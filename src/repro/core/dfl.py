"""The DFL / C-DFL algorithm engine (paper Algorithms 1 and 2).

A *round* is tau1 local SGD steps followed by tau2 gossip steps:

    local update (t in [k]_1):   X_{t+1} = X_t - eta G_t          (Alg. 1 l.4)
    communication (t in [k]_2):  X_{t+1} = X_t C                  (Alg. 1 l.6)

With compression (C-DFL, Alg. 2), the communication sub-round becomes the
CHOCO-G error-feedback iteration over the shared estimates Y = [w_hat^(i)]:

    X <- X + gamma * Y (C - I)                                    (Alg. 2 l.6)
    q  = Q(X - Y)                                                 (Alg. 2 l.7)
    Y <- Y + q                                                    (Alg. 2 l.11)

The algorithm (local-update scan, CHOCO-G step, RNG folding, metrics) is
written ONCE here against the ``repro.core.substrate`` node abstraction and
executed by two engines, selected via ``make_round_fn(..., engine=...)``:

  * ``"dense"``  — every parameter leaf carries a leading node dimension of
                   size N; gossip is the X C einsum (any topology). Pure
                   jit/vmap/scan; distribution is decided by the caller via
                   shardings on the stacked arrays (see ``repro.launch``).
  * ``"sparse"`` — nodes live on manual mesh axes inside ``shard_map``;
                   gossip is per-shift ``ppermute`` (circulant C only, deg
                   neighbor copies instead of N-1). Built by
                   ``repro.core.sharded.make_sharded_round_fn``.
  * ``"auto"``   — sparse iff a mesh is given, its node axes enumerate all
                   N nodes, and ``cfg.topology.is_shift_structured()``.

RNG discipline (identical on both engines, which is what makes
dense-vs-sparse parity exact even for stochastic losses/compressors):
``state.rng`` is a fixed base key; round key = fold_in(rng, round_idx);
local step t key = fold_in(fold_in(round_key, 0), t); gossip step t key =
fold_in(fold_in(round_key, 1), t); per-node key = fold_in(step_key, node).

Supported JAX: 0.4.37 (pinned) and newer — version drift is absorbed by
``repro.core.substrate``, never handled here.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import mixing as mixing_lib
from repro.core.compression import Compressor
from repro.core.substrate import (BatchedSubstrate, DenseSubstrate,
                                  NodeSubstrate, mesh_axis_size)
from repro.core.topology import Topology

PyTree = Any
LossFn = Callable[[PyTree, PyTree, jax.Array], jnp.ndarray]

__all__ = [
    "DFLConfig",
    "DFLState",
    "d_sgd_config",
    "c_sgd_config",
    "sync_sgd_config",
    "replicate",
    "average_model",
    "consensus_distance",
    "init_state",
    "local_phase",
    "gossip_phase",
    "round_keys",
    "make_round_fn",
    "make_pipeline_fns",
    "pipeline_round_body",
    "pipeline_drain_body",
    "round_wire_bits",
]


@dataclasses.dataclass(frozen=True)
class DFLConfig:
    """Hyper-parameters of one DFL instance.

    tau1: computation frequency (local update steps per round).
    tau2: communication frequency (gossip steps per round).
    topology: gossip graph / confusion matrix C.
    mixing_impl: 'dense'       — X C per step (paper-faithful baseline);
                 'dense_power' — X C^{tau2} collapsed into one contraction
                                 (uncompressed DFL only; beyond-paper opt);
                 handled sparsely by the launcher when C is circulant.
    compression: None for plain DFL; a Compressor for C-DFL.
    gamma: CHOCO consensus step size (paper uses 1.0 in Fig. 10).
    """

    tau1: int
    tau2: int
    topology: Topology
    mixing_impl: str = "dense"
    compression: Optional[Compressor] = None
    gamma: float = 1.0
    # optional time-varying topologies: round k uses
    # topology_schedule[k % len] (beyond-paper extension; e.g. alternating
    # ring orientations or random matchings — the theory's zeta becomes the
    # schedule's joint spectral quantity).
    topology_schedule: Tuple[Topology, ...] = ()

    def __post_init__(self):
        assert self.tau1 >= 1 and self.tau2 >= 0
        if self.compression is not None and self.mixing_impl == "dense_power":
            raise ValueError(
                "C-DFL interleaves compression with every gossip step; "
                "dense_power mixing is only valid for uncompressed DFL"
            )

    @property
    def tau(self) -> int:
        return self.tau1 + self.tau2

    @property
    def is_compressed(self) -> bool:
        return self.compression is not None


def d_sgd_config(topology: Topology, **kw) -> DFLConfig:
    """D-SGD special case: (tau1, tau2) = (1, 1)  [paper Sec. III-C1]."""
    return DFLConfig(tau1=1, tau2=1, topology=topology, **kw)


def c_sgd_config(tau: int, topology: Topology, **kw) -> DFLConfig:
    """C-SGD special case: (tau1, tau2) = (tau, 1)  [paper Sec. III-C2]."""
    return DFLConfig(tau1=tau, tau2=1, topology=topology, **kw)


def sync_sgd_config(num_nodes: int, tau1: int = 1, **kw) -> DFLConfig:
    """Synchronous SGD benchmark: C = J (zeta = 0)  [paper Corollary 1/2]."""
    from repro.core.topology import fully_connected

    return DFLConfig(tau1=tau1, tau2=1, topology=fully_connected(num_nodes), **kw)


class DFLState(NamedTuple):
    """Stacked per-node training state."""

    params: PyTree        # every leaf [N, ...]
    opt_state: PyTree     # every leaf [N, ...] (optimizer slots per node)
    hat_params: PyTree    # CHOCO shared estimates Y (None for plain DFL)
    rng: jax.Array        # base PRNG key, folded per step/node
    round_idx: jnp.ndarray  # scalar int32


def replicate(tree: PyTree, n: int) -> PyTree:
    """Stack n identical copies along a new leading node axis (the paper
    initializes all nodes at the same point, Sec. VI-A)."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), tree
    )


def average_model(params: PyTree) -> PyTree:
    """u_t = X_t 1/N (the paper's average model)."""
    return jax.tree_util.tree_map(lambda x: jnp.mean(x, axis=0), params)


def consensus_distance(params: PyTree) -> jnp.ndarray:
    """||X (I - J)||_F^2 / N — the local-drift quantity of Lemma 1."""
    total = 0.0
    n = None
    for leaf in jax.tree_util.tree_leaves(params):
        n = leaf.shape[0]
        mean = jnp.mean(leaf, axis=0, keepdims=True)
        total = total + jnp.sum((leaf.astype(jnp.float32) - mean) ** 2)
    assert n is not None
    return total / n


def init_state(
    params: PyTree, n: int, opt, rng: jax.Array, stacked: bool = False,
    compressed: bool = False,
) -> DFLState:
    """Build the stacked state from single-model params (or pre-stacked).

    ``compressed=True`` allocates the CHOCO shared-estimate tree (Alg. 2
    l.1 initializes w_hat = 0); plain DFL carries None and pays no memory.
    """
    stacked_params = params if stacked else replicate(params, n)
    opt_state = jax.vmap(opt.init)(stacked_params)
    hat = (jax.tree_util.tree_map(jnp.zeros_like, stacked_params)
           if compressed else None)
    return DFLState(
        params=stacked_params,
        opt_state=opt_state,
        hat_params=hat,
        rng=rng,
        round_idx=jnp.zeros((), jnp.int32),
    )


def _local_updates(
    cfg: DFLConfig, loss_fn: LossFn, opt, sub: NodeSubstrate,
    params: PyTree, opt_state: PyTree, local_key: jax.Array, batches: PyTree,
    constrain, tau1=None, node_mask=None,
) -> Tuple[PyTree, PyTree, jnp.ndarray]:
    """tau1 per-node SGD steps (Alg. 1 l.4), engine-agnostic.

    Dense: batch leaves [tau1, N, ...], params [N, ...], sub.vmap = vmap.
    Sparse: batch leaves [tau1, ...] local, params local, sub.vmap = id.

    ``constrain`` re-asserts the stacked-parameter sharding on grads and
    updated params each step: without it GSPMD may resolve the scan carry /
    vmapped-grad shardings to replicated and all-gather entire stacked
    weight trees (observed: 200 GiB/device on phi3.5-moe).

    ``tau1``: optional TRACED int32 step count (the dynamic-tau executor
    path). The batch leading dim is then the compiled bound tau1_max
    (= cfg.tau1) and only the first tau1 slices are read — a
    ``fori_loop`` with a dynamic trip count, so re-planning tau1 never
    retraces. ``None`` keeps the static ``scan`` (bit-identical legacy
    path).

    ``node_mask``: optional traced 0/1 participation mask in the
    substrate's LOCAL view (``sub.node_mask_local``). The update loop runs
    unconditionally — participation gates which nodes KEEP their result
    (``sub.select_nodes``), so the compiled program is mask-independent
    and the all-ones round is a bitwise select of the plain one. The loss
    metric averages over active nodes only.
    """
    grad_one = jax.value_and_grad(loss_fn)
    params0, opt_state0 = params, opt_state

    def step(carry, inp):
        params, opt_state = carry
        batch_t, t = inp
        keys = sub.node_keys(jax.random.fold_in(local_key, t))
        losses, grads = sub.vmap(grad_one)(params, batch_t, keys)
        grads = constrain(grads)
        updates, opt_state = sub.vmap(opt.update)(grads, opt_state, params)
        params = jax.tree_util.tree_map(
            lambda p, u: (p + u).astype(p.dtype), params, updates)
        params = constrain(params)
        return (params, opt_state), losses

    def finish(params, opt_state, per_node_loss):
        if node_mask is None:
            return params, opt_state, sub.mean_over_nodes(per_node_loss)
        params = sub.select_nodes(node_mask, params, params0)
        opt_state = sub.select_nodes(node_mask, opt_state, opt_state0)
        return params, opt_state, sub.masked_mean_over_nodes(
            per_node_loss, node_mask)

    if tau1 is None:
        (params, opt_state), losses = jax.lax.scan(
            step, (params, opt_state), (batches, jnp.arange(cfg.tau1)))
        return finish(params, opt_state, jnp.mean(losses, axis=0))

    def batch_at(t):
        return jax.tree_util.tree_map(
            lambda b: jax.lax.dynamic_index_in_dim(b, t, keepdims=False),
            batches)

    # step 0 runs unconditionally (tau1 >= 1 by DFLConfig), which also
    # yields the per-node loss accumulator's shape/dtype; the summation
    # order (l_0 + l_1 + ...) matches the static path's axis-0 reduce, so
    # dynamic and static rounds stay bitwise identical.
    carry, loss_sum = step((params, opt_state),
                           (batch_at(jnp.zeros((), jnp.int32)),
                            jnp.zeros((), jnp.int32)))

    def body(t, acc):
        carry, loss_sum = acc
        carry, losses = step(carry, (batch_at(t), t))
        return carry, loss_sum + losses

    (params, opt_state), loss_sum = jax.lax.fori_loop(
        1, tau1, body, (carry, loss_sum))
    return finish(params, opt_state, loss_sum / tau1.astype(loss_sum.dtype))


def _communicate_plain(cfg: DFLConfig, sub: NodeSubstrate, params: PyTree,
                       round_idx=None, tau2=None, edge_mask=None) -> PyTree:
    """tau2 uncompressed gossip steps (optionally round-varying topology).

    ``tau2``: optional TRACED int32 gossip count (dynamic-tau executor); the
    ``fori_loop`` trip count is then a device scalar bounded by cfg.tau2
    (the compiled maximum), so schedule changes never retrace. ``None``
    keeps the static legacy path.

    ``edge_mask``: optional traced [E] 0/1 participation mask over
    ``cfg.topology.edges()`` — masked edges gossip identity and their
    weight renormalizes onto the endpoints' self loops (``sub.mix``).
    """
    if tau2 is None and cfg.tau2 == 0:
        return params
    t2 = cfg.tau2 if tau2 is None else tau2
    dense = isinstance(sub, DenseSubstrate)
    if cfg.topology_schedule:
        assert dense and cfg.mixing_impl == "dense", (
            "topology schedules use the dense engine's dense mixing")
        assert edge_mask is None, (
            "participation masks are indexed against cfg.topology.edges(); "
            "a round-varying topology schedule has no stable edge list")
        branches = [
            (lambda p, t=t: jax.lax.fori_loop(
                0, t2, lambda _, q: mixing_lib.mix_dense(q, t), p))
            for t in cfg.topology_schedule
        ]
        sel = (round_idx if round_idx is not None
               else jnp.zeros((), jnp.int32)) % len(branches)
        return jax.lax.switch(sel, branches, params)
    if cfg.mixing_impl == "dense_power":
        assert dense, "dense_power mixing is a dense-engine feature"
        assert tau2 is None, (
            "dense_power collapses tau2 into C^tau2 at trace time; dynamic "
            "taus need iterated mixing (mixing_impl='dense')")
        assert edge_mask is None, (
            "dense_power bakes C^tau2 in at trace time; masked gossip "
            "needs iterated mixing (mixing_impl='dense')")
        return mixing_lib.mix_dense_power(params, cfg.topology, cfg.tau2)
    if cfg.mixing_impl != "dense":
        raise ValueError(f"unknown mixing_impl {cfg.mixing_impl!r}")
    return jax.lax.fori_loop(
        0, t2, lambda _, p: sub.mix(p, edge_mask=edge_mask), params)


def _communicate_choco(
    cfg: DFLConfig, params: PyTree, hat: PyTree, rng: jax.Array,
    sub: Optional[NodeSubstrate] = None, tau2=None, edge_mask=None,
) -> Tuple[PyTree, PyTree]:
    """tau2 CHOCO-G compressed gossip steps (Alg. 2 lines 6-11), shared by
    both engines: Y is mixed by ``sub.mix`` (dense einsum / ppermute), then
    ``sub.choco_step`` runs the move + compress + estimate update — the
    unfused composition by default, or the single-pass fused kernel on the
    sharded substrate under ``use_kernels`` — with per-node keys
    fold_in(fold_in(rng, t), node) on either substrate.

    ``tau2``: optional TRACED int32 step count (dynamic-tau executor) —
    the same iteration body runs under a dynamic-trip-count ``fori_loop``
    instead of the static ``scan``, with identical per-step key folding.
    """
    comp = cfg.compression
    assert comp is not None
    sub = sub if sub is not None else DenseSubstrate(cfg.topology)

    def one_step(carry, t):
        x, y = carry
        mixed_y = sub.mix(y, edge_mask=edge_mask)
        keys = sub.node_keys(jax.random.fold_in(rng, t))
        return sub.choco_step(comp, x, y, mixed_y, cfg.gamma, keys)

    if tau2 is None:
        (params, hat), _ = jax.lax.scan(
            lambda c, t: (one_step(c, t), None), (params, hat),
            jnp.arange(cfg.tau2)
        )
        return params, hat
    params, hat = jax.lax.fori_loop(
        0, tau2, lambda t, c: one_step(c, t), (params, hat))
    return params, hat


def round_keys(rng: jax.Array, round_idx) -> Tuple[jax.Array, jax.Array]:
    """(local_key, comm_key) for one round — THE folding discipline; both
    engines must derive their keys from here (see module docstring)."""
    round_key = jax.random.fold_in(rng, round_idx)
    return jax.random.fold_in(round_key, 0), jax.random.fold_in(round_key, 1)


def local_phase(
    cfg: DFLConfig, loss_fn: LossFn, opt, sub: NodeSubstrate,
    params: PyTree, opt_state: PyTree, local_key: jax.Array, batches: PyTree,
    constrain=None, tau1=None, node_mask=None,
) -> Tuple[PyTree, PyTree, jnp.ndarray]:
    """Stage 1 of a round: tau1 local SGD steps (Alg. 1 l.4).

    Thin named wrapper over ``_local_updates`` so callers (the overlapped
    executor, tests' pure-Python references) can compose the two round
    stages explicitly. ``node_mask`` is the substrate-LOCAL participation
    view (``sub.node_mask_local``). Returns (params', opt_state',
    mean_loss).
    """
    constrain = constrain or (lambda t: t)
    return _local_updates(cfg, loss_fn, opt, sub, params, opt_state,
                          local_key, batches, constrain, tau1=tau1,
                          node_mask=node_mask)


def gossip_phase(
    cfg: DFLConfig, sub: NodeSubstrate, params: PyTree, hat: Optional[PyTree],
    comm_key: jax.Array, round_idx, constrain=None, tau2=None, edge_mask=None,
) -> Tuple[PyTree, Optional[PyTree]]:
    """Stage 2 of a round: tau2 gossip steps (Alg. 1 l.6 / Alg. 2 l.6-11).

    Plain DFL mixes ``params`` and re-asserts ``constrain``; C-DFL runs the
    CHOCO-G error-feedback iteration over (params, hat). Returns
    (params', hat') with hat' = None on the plain path. The exchange this
    stage issues belongs to round ``round_idx`` (topology-schedule branch
    selection and the comm-key derivation agree on that index).
    """
    constrain = constrain or (lambda t: t)
    if cfg.is_compressed:
        assert hat is not None, "C-DFL needs init_state(..., compressed=True)"
        params, hat = _communicate_choco(cfg, params, hat, comm_key, sub,
                                         tau2=tau2, edge_mask=edge_mask)
    else:
        params = _communicate_plain(cfg, sub, params, round_idx, tau2=tau2,
                                    edge_mask=edge_mask)
        params = constrain(params)
    return params, hat


def round_body(
    cfg: DFLConfig, loss_fn: LossFn, opt, sub: NodeSubstrate,
    params: PyTree, opt_state: PyTree, hat: Optional[PyTree],
    rng: jax.Array, round_idx, batches: PyTree, constrain=None,
    taus: Optional[Tuple[jax.Array, jax.Array]] = None,
    masks: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[PyTree, PyTree, Optional[PyTree], dict]:
    """One full DFL/C-DFL round on either substrate: the single shared
    implementation both engines execute.

    ``taus``: optional ``(tau1, tau2)`` TRACED int32 scalars — the
    dynamic-tau executor path. ``cfg.tau1``/``cfg.tau2`` then act as the
    compiled maxima (batch leading dim / loop bounds) and the scalars pick
    the step counts actually run, so an adaptive re-plan changes them
    without retracing. RNG folding and per-step arithmetic are identical to
    the static path (bit-for-bit, tested in tests/test_executor.py).

    ``masks``: optional ``(node_mask [N], edge_mask [E])`` TRACED 0/1 int32
    vectors (REPLICATED on the sparse engine) — the sporadic-participation
    path. Masked nodes skip their local updates (keep params/opt slots,
    still gossip); masked edges gossip identity with the lost weight
    renormalized onto both endpoints' self loops, so the effective W stays
    doubly stochastic. The compiled program is mask-independent (masks
    gate selects and accumulation weights, never control flow), and the
    all-ones round is bitwise the unmasked one (tests/test_faults.py).
    RNG folding is untouched by masks.
    """
    constrain = constrain or (lambda t: t)
    tau1, tau2 = taus if taus is not None else (None, None)
    if masks is not None:
        node_mask, edge_mask = masks
        mask_local = sub.node_mask_local(node_mask)
    else:
        mask_local = edge_mask = None
    local_key, comm_key = round_keys(rng, round_idx)
    params, opt_state, mean_loss = local_phase(
        cfg, loss_fn, opt, sub, params, opt_state, local_key, batches,
        constrain, tau1=tau1, node_mask=mask_local)
    params, hat = gossip_phase(cfg, sub, params, hat, comm_key, round_idx,
                               constrain, tau2=tau2, edge_mask=edge_mask)
    metrics = {
        "loss": mean_loss,
        "consensus_sq": sub.consensus_sq(params),
    }
    return params, opt_state, hat, metrics


def make_round_fn(
    cfg: DFLConfig, loss_fn: LossFn, opt, constrain=None, *,
    engine: str = "dense", mesh=None, node_axes: Sequence[str] = ("data",),
    use_kernels: bool = False, dynamic_taus: bool = False,
    participation: bool = False, population: Optional[int] = None,
) -> Callable[..., Tuple[DFLState, dict]]:
    """Build the jittable one-round function for either engine.

    round_fn(state, batches) -> (state', metrics); batches leaves
    [tau1, N, local_batch...]. ``constrain``: optional params-tree sharding
    re-assertion (see _local_updates). The sparse engine's node axes are
    shard_map-manual so the node-dim constraint is structural there, but
    its non-node (auto) axes run unconstrained: passing a ``constrain``
    to the sparse engine on a mesh with a >1-sized auto axis RAISES
    (``make_sharded_round_fn``) instead of silently dropping it — the
    scan-carry all-gather blowup documented in _local_updates would
    otherwise return the moment partial-auto meshes are enabled. On
    node-only meshes (every auto axis size 1) there is nothing to
    re-assert and the sparse engine accepts-and-ignores it.

    engine: "dense" (default; any topology), "sparse" (shard_map +
    ppermute; needs ``mesh`` whose ``node_axes`` enumerate all N nodes and
    a shift-structured topology), "batched" (node-batched virtual
    population — see below), or "auto" (batched when a ``population`` is
    given, else sparse when eligible, else dense).
    ``use_kernels`` routes the sparse hot path through the Pallas kernels.

    ``dynamic_taus``: the returned function is
    round_fn(state, batches, tau1, tau2) with DEVICE-SCALAR step counts;
    cfg.tau1/cfg.tau2 become the compiled maxima (batches carry
    [cfg.tau1, ...] leading dims, only the first tau1 slices are read).
    One compile covers every (tau1, tau2) <= the maxima — the
    recompile-free hot path behind ``repro.core.executor``.

    ``participation``: the returned function is
    round_fn(state, batches, tau1, tau2, node_mask, edge_mask) with traced
    0/1 int32 masks ([N] over nodes, [E] over ``topology.edges()``) — the
    sporadic round semantic of ``round_body(..., masks=...)``. Requires
    ``dynamic_taus`` (masks ride the same schedule-as-data path) and plain
    per-step mixing (no dense_power / topology_schedule).

    ``population``: the node-batched mega-scale path (engine="batched").
    State leaves are stacked ``[population, ...]`` while ``cfg.topology``
    is the C-node COHORT graph; the returned function is
    round_fn(state, batches, tau1, tau2, cohort_ids, node_mask, edge_mask)
    with a traced ``[C]`` int32 vector of global virtual-node ids plus the
    usual participation masks over the cohort topology. Each round gathers
    the cohort rows, runs the UNCHANGED shared ``round_body`` (per-node
    keys fold the global ids — ``BatchedSubstrate.node_keys``), and
    scatters back; non-cohort nodes are bitwise frozen. At full population
    with identity ids the round is bitwise the dense engine's
    (tests/test_batched_parity.py). Implies the ``participation``
    constraints (dynamic taus, per-step mixing, no topology schedule).
    """
    if dynamic_taus and cfg.mixing_impl == "dense_power":
        raise ValueError(
            "dynamic taus need iterated mixing: dense_power bakes C^tau2 in "
            "at trace time (use mixing_impl='dense')")
    if participation or population is not None:
        if not dynamic_taus:
            raise ValueError(
                "participation masks ride the dynamic schedule-as-data "
                "path; pass dynamic_taus=True")
        if cfg.topology_schedule:
            raise ValueError(
                "participation masks index cfg.topology.edges(); a "
                "round-varying topology schedule has no stable edge list")
    if engine == "auto":
        if population is not None:
            # the population exceeds what any mesh enumerates: nodes must
            # be data, not hardware (docs/ARCHITECTURE.md engine rules).
            engine = "batched"
        else:
            engine = "sparse" if sparse_engine_eligible(
                cfg, mesh, node_axes) else "dense"
    if engine == "batched":
        if population is None:
            raise ValueError(
                "engine='batched' needs population=V (the virtual node "
                "count the state leaves are stacked over)")
        # build-time validation (population >= cohort size) happens here,
        # not inside the trace.
        BatchedSubstrate(cfg.topology, population)

        def batched_round_fn(state: DFLState, batches: PyTree, tau1, tau2,
                             cohort_ids, node_mask, edge_mask):
            sub = BatchedSubstrate(cfg.topology, population,
                                   jnp.asarray(cohort_ids, jnp.int32))
            params = sub.gather_cohort(state.params)
            opt_state = sub.gather_cohort(state.opt_state)
            hat = (sub.gather_cohort(state.hat_params)
                   if state.hat_params is not None else None)
            params, opt_state, hat, metrics = round_body(
                cfg, loss_fn, opt, sub, params, opt_state, hat,
                state.rng, state.round_idx, batches, constrain,
                taus=(jnp.asarray(tau1, jnp.int32),
                      jnp.asarray(tau2, jnp.int32)),
                masks=(jnp.asarray(node_mask, jnp.int32),
                       jnp.asarray(edge_mask, jnp.int32)))
            state = state._replace(
                params=sub.scatter_cohort(state.params, params),
                opt_state=sub.scatter_cohort(state.opt_state, opt_state),
                hat_params=(sub.scatter_cohort(state.hat_params, hat)
                            if hat is not None else None),
                round_idx=state.round_idx + 1)
            return state, metrics

        return batched_round_fn
    if population is not None:
        raise ValueError(
            f"population= is a batched-engine parameter (got engine="
            f"{engine!r}); the {engine} engine's node count IS the "
            "topology's")
    if engine == "sparse":
        from repro.core.sharded import make_sharded_round_fn

        assert mesh is not None, "sparse engine needs a mesh"
        return make_sharded_round_fn(cfg, loss_fn, opt, mesh,
                                     node_axes=node_axes,
                                     use_kernels=use_kernels,
                                     dynamic_taus=dynamic_taus,
                                     participation=participation,
                                     constrain=constrain)
    if engine != "dense":
        raise ValueError(f"unknown engine {engine!r}")
    sub = DenseSubstrate(cfg.topology)

    def body(state: DFLState, batches: PyTree, taus, masks=None):
        params, opt_state, hat, metrics = round_body(
            cfg, loss_fn, opt, sub, state.params, state.opt_state,
            state.hat_params, state.rng, state.round_idx, batches, constrain,
            taus=taus, masks=masks)
        state = state._replace(
            params=params, opt_state=opt_state, hat_params=hat,
            round_idx=state.round_idx + 1)
        return state, metrics

    if participation:
        def round_fn(state: DFLState, batches: PyTree, tau1, tau2,
                     node_mask, edge_mask):
            return body(state, batches,
                        (jnp.asarray(tau1, jnp.int32),
                         jnp.asarray(tau2, jnp.int32)),
                        masks=(jnp.asarray(node_mask, jnp.int32),
                               jnp.asarray(edge_mask, jnp.int32)))
    elif dynamic_taus:
        def round_fn(state: DFLState, batches: PyTree, tau1, tau2):
            return body(state, batches,
                        (jnp.asarray(tau1, jnp.int32),
                         jnp.asarray(tau2, jnp.int32)))
    else:
        def round_fn(state: DFLState, batches: PyTree):
            return body(state, batches, None)

    return round_fn


def pipeline_round_body(
    cfg: DFLConfig, loss_fn: LossFn, opt, sub: NodeSubstrate,
    params: PyTree, opt_state: PyTree, hat: Optional[PyTree],
    rng: jax.Array, round_idx, buf: PyTree, have, tau1, prev_tau2,
    batches: PyTree, constrain=None, node_mask=None, prev_edge_mask=None,
) -> Tuple[PyTree, PyTree, Optional[PyTree], PyTree, dict]:
    """One OVERLAPPED round: round k's local phase plus the one-round-stale
    fold of round k-1's gossip exchange (``overlap="pipeline"``).

    Dataflow (k = the round at ``round_idx``)::

        z_k = local_phase(p_k, batches_k)            # round k's tau1 steps
        g   = gossip_phase(buf = z_{k-1}, ...)       # round k-1's exchange,
                                                     #   INDEPENDENT of z_k
        p_{k+1} = z_k + (g - z_{k-1})                # fold one round late

    Because ``g`` depends only on the carried buffer, the tau2 ppermute
    exchange of round k-1 and the tau1 local updates of round k are
    independent in the compiled dataflow — the scheduler may issue the
    collective before/under the compute (the overlap the planner's
    ``max(0, tau2*T_gossip - overlap_window)`` round-time model prices).
    The cost is one round of mixing staleness: the delayed-mixing regime
    priced by ``planner.bounds.stale_mixing_zeta``.

    The stale exchange uses round k-1's comm key, trip count
    (``prev_tau2``) and edge mask, so a pipelined run applies exactly the
    same gossip operators as the legacy run, each one round later.
    ``have`` is a traced 0/1 scalar: 0 on the first scan iteration, where
    the exchange still runs (collective matching / mask-independence) but
    its fold is discarded bitwise. CHOCO's shared estimates ride the gossip
    chain sequentially (hat is only ever advanced by exchanges), so they
    need no extra buffer — just the same discard on iteration 0.

    Returns (params', opt_state', hat', buf'=z_k, metrics). The loss
    metric is round k's; ``consensus_sq`` is measured on the folded params.
    """
    constrain = constrain or (lambda t: t)
    if node_mask is not None:
        mask_local = sub.node_mask_local(node_mask)
    else:
        mask_local = None
    local_key, _ = round_keys(rng, round_idx)
    _, stale_comm_key = round_keys(rng, round_idx - 1)
    z, opt_state, mean_loss = local_phase(
        cfg, loss_fn, opt, sub, params, opt_state, local_key, batches,
        constrain, tau1=tau1, node_mask=mask_local)
    g, hat_g = gossip_phase(cfg, sub, buf, hat, stale_comm_key,
                            round_idx - 1, constrain, tau2=prev_tau2,
                            edge_mask=prev_edge_mask)
    keep = have != 0
    params = jax.tree_util.tree_map(
        lambda zl, gl, bl: jnp.where(keep, (zl + (gl - bl)).astype(zl.dtype),
                                     zl), z, g, buf)
    params = constrain(params)
    if cfg.is_compressed:
        hat = jax.tree_util.tree_map(
            lambda a, b: jnp.where(keep, a, b), hat_g, hat)
    metrics = {
        "loss": mean_loss,
        "consensus_sq": sub.consensus_sq(params),
    }
    return params, opt_state, hat, z, metrics


def pipeline_drain_body(
    cfg: DFLConfig, sub: NodeSubstrate, params: PyTree, hat: Optional[PyTree],
    rng: jax.Array, round_idx, buf: PyTree, prev_tau2, constrain=None,
    prev_edge_mask=None,
) -> Tuple[PyTree, Optional[PyTree]]:
    """Retire the in-flight exchange after a pipelined scan.

    ``round_idx`` is the POST-scan counter, so the outstanding exchange
    belongs to round ``round_idx - 1`` (its comm key / trip count / edge
    mask). Runs in the same executable as the scan, so a dispatched
    superstep always returns fully-drained state: no gossip crosses a
    superstep / checkpoint / restore boundary.
    """
    constrain = constrain or (lambda t: t)
    _, stale_comm_key = round_keys(rng, round_idx - 1)
    g, hat = gossip_phase(cfg, sub, buf, hat, stale_comm_key, round_idx - 1,
                          constrain, tau2=prev_tau2,
                          edge_mask=prev_edge_mask)
    params = jax.tree_util.tree_map(
        lambda pl, gl, bl: (pl + (gl - bl)).astype(pl.dtype), params, g, buf)
    params = constrain(params)
    return params, hat


def make_pipeline_fns(
    cfg: DFLConfig, loss_fn: LossFn, opt, constrain=None, *,
    engine: str = "dense", mesh=None, node_axes: Sequence[str] = ("data",),
    use_kernels: bool = False, participation: bool = False,
) -> Tuple[Callable[..., Tuple[DFLState, PyTree, dict]],
           Callable[..., DFLState]]:
    """Build the jittable pipelined-round pair for either engine
    (``overlap="pipeline"``; the executor scans ``pipe_fn`` and calls
    ``drain_fn`` once after the scan — see
    ``core.executor.make_pipeline_superstep``).

    Signatures (all step counts / flags are traced int32)::

        pipe_fn(state, buf, have, prev_tau2, batches, tau1)
            -> (state', buf', metrics)                       # plain
        pipe_fn(state, buf, have, prev_tau2, prev_edge_mask,
                batches, tau1, node_mask)
            -> (state', buf', metrics)                       # participation
        drain_fn(state, buf, prev_tau2[, prev_edge_mask]) -> state'

    The CURRENT round's (tau2, edge_mask) never enter ``pipe_fn``: that
    exchange is issued one scan iteration later from the carry (the whole
    point of the pipeline). The pipeline is dynamic-only — cfg.tau1 /
    cfg.tau2 are the compiled maxima exactly as in the dynamic round path.
    """
    if cfg.mixing_impl == "dense_power":
        raise ValueError(
            "overlap='pipeline' is dynamic-only: dense_power bakes C^tau2 "
            "in at trace time (use mixing_impl='dense')")
    if engine == "batched":
        raise ValueError(
            "overlap='pipeline' is not supported on the batched engine: "
            "consecutive rounds gossip over DIFFERENT sampled cohorts, so "
            "the in-flight exchange has no stable buffer to double-buffer "
            "(use overlap='none')")
    if participation and cfg.topology_schedule:
        raise ValueError(
            "participation masks index cfg.topology.edges(); a "
            "round-varying topology schedule has no stable edge list")
    if engine == "auto":
        engine = "sparse" if sparse_engine_eligible(
            cfg, mesh, node_axes) else "dense"
    if engine == "sparse":
        from repro.core.sharded import make_sharded_pipeline_fns

        assert mesh is not None, "sparse engine needs a mesh"
        return make_sharded_pipeline_fns(cfg, loss_fn, opt, mesh,
                                         node_axes=node_axes,
                                         use_kernels=use_kernels,
                                         participation=participation,
                                         constrain=constrain)
    if engine != "dense":
        raise ValueError(f"unknown engine {engine!r}")
    sub = DenseSubstrate(cfg.topology)

    def pipe_body(state: DFLState, buf, have, prev_tau2, batches, tau1,
                  node_mask=None, prev_edge_mask=None):
        params, opt_state, hat, z, metrics = pipeline_round_body(
            cfg, loss_fn, opt, sub, state.params, state.opt_state,
            state.hat_params, state.rng, state.round_idx, buf, have, tau1,
            prev_tau2, batches, constrain, node_mask=node_mask,
            prev_edge_mask=prev_edge_mask)
        state = state._replace(
            params=params, opt_state=opt_state, hat_params=hat,
            round_idx=state.round_idx + 1)
        return state, z, metrics

    def drain_body(state: DFLState, buf, prev_tau2, prev_edge_mask=None):
        params, hat = pipeline_drain_body(
            cfg, sub, state.params, state.hat_params, state.rng,
            state.round_idx, buf, prev_tau2, constrain,
            prev_edge_mask=prev_edge_mask)
        return state._replace(params=params, hat_params=hat)

    if participation:
        def pipe_fn(state, buf, have, prev_tau2, prev_edge_mask, batches,
                    tau1, node_mask):
            return pipe_body(state, buf, jnp.asarray(have, jnp.int32),
                             jnp.asarray(prev_tau2, jnp.int32), batches,
                             jnp.asarray(tau1, jnp.int32),
                             node_mask=jnp.asarray(node_mask, jnp.int32),
                             prev_edge_mask=jnp.asarray(prev_edge_mask,
                                                        jnp.int32))

        def drain_fn(state, buf, prev_tau2, prev_edge_mask):
            return drain_body(state, buf, jnp.asarray(prev_tau2, jnp.int32),
                              prev_edge_mask=jnp.asarray(prev_edge_mask,
                                                         jnp.int32))
    else:
        def pipe_fn(state, buf, have, prev_tau2, batches, tau1):
            return pipe_body(state, buf, jnp.asarray(have, jnp.int32),
                             jnp.asarray(prev_tau2, jnp.int32), batches,
                             jnp.asarray(tau1, jnp.int32))

        def drain_fn(state, buf, prev_tau2):
            return drain_body(state, buf, jnp.asarray(prev_tau2, jnp.int32))

    return pipe_fn, drain_fn


def sparse_engine_eligible(cfg: DFLConfig, mesh,
                           node_axes: Sequence[str]) -> bool:
    """True when the sparse (shard_map + ppermute) engine can run this
    config on this mesh: circulant topology, no dense-only features, and
    the node mesh axes enumerate exactly the N > 1 nodes."""
    if mesh is None or cfg.topology_schedule or cfg.mixing_impl != "dense":
        return False
    if not cfg.topology.is_shift_structured():
        return False
    n = cfg.topology.num_nodes
    if n <= 1:
        return False
    try:
        mesh_n = mesh_axis_size(mesh, tuple(node_axes))
    except KeyError:
        return False
    if mesh_n != n:
        return False
    # Non-node mesh axes stay auto (GSPMD) inside the sparse engine's
    # shard_map; on JAX pins whose partial-manual mode is broken, only
    # size-1 auto axes are safe (see substrate.supports_partial_auto).
    from repro.core import substrate as substrate_lib

    other = [a for a in mesh.axis_names if a not in node_axes]
    if any(mesh.shape[a] > 1 for a in other):
        return substrate_lib.supports_partial_auto()
    return True


def round_wire_bits(cfg: DFLConfig, params_one_node: PyTree,
                    engine: str = "sparse") -> float:
    """Analytic wire bits per node per ROUND (tau2 gossip steps).

    Uncompressed: each gossip step ships the full fp32 model per received
    copy; compressed: Q's bits_per_value. The copy count comes from
    ``mixing.gossip_copies_per_step(topology, engine)``: engine="sparse"
    (default) charges per-neighbor traffic — the paper's deployment
    accounting and the ppermute engine's actual cost — while "dense"
    charges the dense all-gather lowering's N-1 copies. Used by the
    Fig.-10-style wall-clock-per-bit benchmarks.
    """
    from repro.core.compression import Identity, tree_wire_bits

    comp = cfg.compression if cfg.is_compressed else Identity()
    copies = mixing_lib.gossip_copies_per_step(cfg.topology, engine)
    per_step = tree_wire_bits(comp, params_one_node) * copies
    return per_step * cfg.tau2
