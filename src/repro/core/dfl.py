"""The DFL / C-DFL algorithm engine (paper Algorithms 1 and 2).

A *round* is tau1 local SGD steps followed by tau2 gossip steps:

    local update (t in [k]_1):   X_{t+1} = X_t - eta G_t          (Alg. 1 l.4)
    communication (t in [k]_2):  X_{t+1} = X_t C                  (Alg. 1 l.6)

With compression (C-DFL, Alg. 2), the communication sub-round becomes the
CHOCO-G error-feedback iteration over the shared estimates Y = [w_hat^(i)]:

    X <- X + gamma * Y (C - I)                                    (Alg. 2 l.6)
    q  = Q(X - Y)                                                 (Alg. 2 l.7)
    Y <- Y + q                                                    (Alg. 2 l.11)

Every parameter leaf carries a leading node dimension of size N. The engine
is pure JAX (jit/vmap/scan) and device-layout agnostic: distribution is
decided by the caller via shardings on the stacked arrays (see
``repro.launch.train``) or by wrapping in ``shard_map`` (sparse mixing).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mixing as mixing_lib
from repro.core.compression import Compressor, compress_tree
from repro.core.topology import Topology

PyTree = Any
LossFn = Callable[[PyTree, PyTree, jax.Array], jnp.ndarray]

__all__ = [
    "DFLConfig",
    "DFLState",
    "d_sgd_config",
    "c_sgd_config",
    "sync_sgd_config",
    "replicate",
    "average_model",
    "consensus_distance",
    "init_state",
    "make_round_fn",
    "round_wire_bits",
]


@dataclasses.dataclass(frozen=True)
class DFLConfig:
    """Hyper-parameters of one DFL instance.

    tau1: computation frequency (local update steps per round).
    tau2: communication frequency (gossip steps per round).
    topology: gossip graph / confusion matrix C.
    mixing_impl: 'dense'       — X C per step (paper-faithful baseline);
                 'dense_power' — X C^{tau2} collapsed into one contraction
                                 (uncompressed DFL only; beyond-paper opt);
                 handled sparsely by the launcher when C is circulant.
    compression: None for plain DFL; a Compressor for C-DFL.
    gamma: CHOCO consensus step size (paper uses 1.0 in Fig. 10).
    """

    tau1: int
    tau2: int
    topology: Topology
    mixing_impl: str = "dense"
    compression: Optional[Compressor] = None
    gamma: float = 1.0
    # optional time-varying topologies: round k uses
    # topology_schedule[k % len] (beyond-paper extension; e.g. alternating
    # ring orientations or random matchings — the theory's zeta becomes the
    # schedule's joint spectral quantity).
    topology_schedule: Tuple[Topology, ...] = ()

    def __post_init__(self):
        assert self.tau1 >= 1 and self.tau2 >= 0
        if self.compression is not None and self.mixing_impl == "dense_power":
            raise ValueError(
                "C-DFL interleaves compression with every gossip step; "
                "dense_power mixing is only valid for uncompressed DFL"
            )

    @property
    def tau(self) -> int:
        return self.tau1 + self.tau2

    @property
    def is_compressed(self) -> bool:
        return self.compression is not None


def d_sgd_config(topology: Topology, **kw) -> DFLConfig:
    """D-SGD special case: (tau1, tau2) = (1, 1)  [paper Sec. III-C1]."""
    return DFLConfig(tau1=1, tau2=1, topology=topology, **kw)


def c_sgd_config(tau: int, topology: Topology, **kw) -> DFLConfig:
    """C-SGD special case: (tau1, tau2) = (tau, 1)  [paper Sec. III-C2]."""
    return DFLConfig(tau1=tau, tau2=1, topology=topology, **kw)


def sync_sgd_config(num_nodes: int, tau1: int = 1, **kw) -> DFLConfig:
    """Synchronous SGD benchmark: C = J (zeta = 0)  [paper Corollary 1/2]."""
    from repro.core.topology import fully_connected

    return DFLConfig(tau1=tau1, tau2=1, topology=fully_connected(num_nodes), **kw)


class DFLState(NamedTuple):
    """Stacked per-node training state."""

    params: PyTree        # every leaf [N, ...]
    opt_state: PyTree     # every leaf [N, ...] (optimizer slots per node)
    hat_params: PyTree    # CHOCO shared estimates Y (None for plain DFL)
    rng: jax.Array        # base PRNG key, folded per step/node
    round_idx: jnp.ndarray  # scalar int32


def replicate(tree: PyTree, n: int) -> PyTree:
    """Stack n identical copies along a new leading node axis (the paper
    initializes all nodes at the same point, Sec. VI-A)."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), tree
    )


def average_model(params: PyTree) -> PyTree:
    """u_t = X_t 1/N (the paper's average model)."""
    return jax.tree_util.tree_map(lambda x: jnp.mean(x, axis=0), params)


def consensus_distance(params: PyTree) -> jnp.ndarray:
    """||X (I - J)||_F^2 / N — the local-drift quantity of Lemma 1."""
    total = 0.0
    n = None
    for leaf in jax.tree_util.tree_leaves(params):
        n = leaf.shape[0]
        mean = jnp.mean(leaf, axis=0, keepdims=True)
        total = total + jnp.sum((leaf.astype(jnp.float32) - mean) ** 2)
    assert n is not None
    return total / n


def init_state(
    params: PyTree, n: int, opt, rng: jax.Array, stacked: bool = False,
    compressed: bool = False,
) -> DFLState:
    """Build the stacked state from single-model params (or pre-stacked).

    ``compressed=True`` allocates the CHOCO shared-estimate tree (Alg. 2
    l.1 initializes w_hat = 0); plain DFL carries None and pays no memory.
    """
    stacked_params = params if stacked else replicate(params, n)
    opt_state = jax.vmap(opt.init)(stacked_params)
    hat = (jax.tree_util.tree_map(jnp.zeros_like, stacked_params)
           if compressed else None)
    return DFLState(
        params=stacked_params,
        opt_state=opt_state,
        hat_params=hat,
        rng=rng,
        round_idx=jnp.zeros((), jnp.int32),
    )


def _local_updates(
    cfg: DFLConfig, loss_fn: LossFn, opt, state: DFLState, batches: PyTree,
    constrain=None,
) -> Tuple[DFLState, jnp.ndarray]:
    """tau1 per-node SGD steps; batches leaves are [tau1, N, ...].

    ``constrain`` (optional) re-asserts the stacked-parameter sharding on
    grads and updated params each step: without it GSPMD may resolve the
    scan carry / vmapped-grad shardings to replicated and all-gather entire
    stacked weight trees (observed: 200 GiB/device on phi3.5-moe).
    """
    constrain = constrain or (lambda t: t)

    def loss_one(params_i, batch_i, key_i):
        return loss_fn(params_i, batch_i, key_i)

    grad_one = jax.value_and_grad(loss_one)

    def step(carry, inp):
        params, opt_state, rng = carry
        batch_t, t = inp
        rng, sub = jax.random.split(rng)
        n = jax.tree_util.tree_leaves(params)[0].shape[0]
        keys = jax.random.split(sub, n)
        losses, grads = jax.vmap(grad_one)(params, batch_t, keys)
        grads = constrain(grads)
        updates, opt_state = jax.vmap(opt.update)(grads, opt_state, params)
        params = jax.tree_util.tree_map(
            lambda p, u: (p + u).astype(p.dtype), params, updates)
        params = constrain(params)
        return (params, opt_state, rng), jnp.mean(losses)

    (params, opt_state, rng), losses = jax.lax.scan(
        step,
        (state.params, state.opt_state, state.rng),
        (batches, jnp.arange(cfg.tau1)),
    )
    new_state = state._replace(params=params, opt_state=opt_state, rng=rng)
    return new_state, jnp.mean(losses)


def _communicate_plain(cfg: DFLConfig, params: PyTree,
                       round_idx=None) -> PyTree:
    """tau2 uncompressed gossip steps (optionally round-varying topology)."""
    if cfg.tau2 == 0:
        return params
    if cfg.topology_schedule:
        assert cfg.mixing_impl == "dense", (
            "topology schedules use dense mixing")
        branches = [
            (lambda p, t=t: jax.lax.fori_loop(
                0, cfg.tau2, lambda _, q: mixing_lib.mix_dense(q, t), p))
            for t in cfg.topology_schedule
        ]
        sel = (round_idx if round_idx is not None
               else jnp.zeros((), jnp.int32)) % len(branches)
        return jax.lax.switch(sel, branches, params)
    if cfg.mixing_impl == "dense_power":
        return mixing_lib.mix_dense_power(params, cfg.topology, cfg.tau2)
    if cfg.mixing_impl != "dense":
        raise ValueError(f"unknown mixing_impl {cfg.mixing_impl!r}")

    def body(_, p):
        return mixing_lib.mix_dense(p, cfg.topology)

    return jax.lax.fori_loop(0, cfg.tau2, body, params)


def _communicate_choco(
    cfg: DFLConfig, params: PyTree, hat: PyTree, rng: jax.Array
) -> Tuple[PyTree, PyTree]:
    """tau2 CHOCO-G compressed gossip steps (Alg. 2 lines 6-11)."""
    comp = cfg.compression
    assert comp is not None
    c_minus_i = cfg.topology.mixing - np.eye(cfg.topology.num_nodes)
    gamma = cfg.gamma

    def one_step(carry, t):
        x, y = carry

        def move_leaf(x_leaf, y_leaf):
            cm = jnp.asarray(c_minus_i, dtype=jnp.float32)
            delta = jnp.einsum("ji,j...->i...", cm, y_leaf.astype(jnp.float32))
            return (x_leaf.astype(jnp.float32) + gamma * delta).astype(x_leaf.dtype)

        x_new = jax.tree_util.tree_map(move_leaf, x, y)
        step_key = jax.random.fold_in(rng, t)
        # Q applied per node (independent randomness per node).
        n = jax.tree_util.tree_leaves(x_new)[0].shape[0]
        node_keys = jax.random.split(step_key, n)
        diff = jax.tree_util.tree_map(lambda a, b: a - b, x_new, y)
        q = jax.vmap(lambda d, k: compress_tree(comp, d, k))(diff, node_keys)
        y_new = jax.tree_util.tree_map(lambda b, qq: b + qq, y, q)
        return (x_new, y_new), None

    (params, hat), _ = jax.lax.scan(
        one_step, (params, hat), jnp.arange(cfg.tau2)
    )
    return params, hat


def make_round_fn(
    cfg: DFLConfig, loss_fn: LossFn, opt, constrain=None
) -> Callable[[DFLState, PyTree], Tuple[DFLState, dict]]:
    """Build the jittable one-round function.

    round_fn(state, batches) -> (state', metrics); batches leaves
    [tau1, N, local_batch...]. ``constrain``: optional params-tree sharding
    re-assertion (see _local_updates).
    """

    def round_fn(state: DFLState, batches: PyTree):
        state, mean_loss = _local_updates(cfg, loss_fn, opt, state, batches,
                                          constrain)
        if cfg.is_compressed:
            assert state.hat_params is not None, (
                "C-DFL needs init_state(..., compressed=True)")
            rng, sub = jax.random.split(state.rng)
            params, hat = _communicate_choco(cfg, state.params, state.hat_params, sub)
            state = state._replace(params=params, hat_params=hat, rng=rng)
        else:
            params = _communicate_plain(cfg, state.params, state.round_idx)
            if constrain is not None:
                params = constrain(params)
            state = state._replace(params=params)
        state = state._replace(round_idx=state.round_idx + 1)
        metrics = {
            "loss": mean_loss,
            "consensus_sq": consensus_distance(state.params),
        }
        return state, metrics

    return round_fn


def round_wire_bits(cfg: DFLConfig, params_one_node: PyTree) -> float:
    """Analytic wire bits per node per ROUND (tau2 gossip steps).

    Uncompressed: each gossip step ships the full fp32 model to each
    neighbor; compressed: Q's bits_per_value. Used by the Fig.-10-style
    wall-clock-per-bit benchmarks.
    """
    from repro.core.compression import Identity, tree_wire_bits

    comp = cfg.compression if cfg.is_compressed else Identity()
    deg = cfg.topology.max_degree
    per_step = tree_wire_bits(comp, params_one_node) * deg
    return per_step * cfg.tau2
