"""Recompile-free fused round executor: the DFL hot-loop dispatcher.

The paper's balancing result only pays off if *changing* the (tau1, tau2)
schedule is cheap; resource-constrained DFL work (Yan & Li 2023) wants it
re-planned per round. Before this module, every adaptive re-plan rebuilt and
re-jitted the round function (tau1/tau2 were static scan lengths), so the
controller had to discard compile-contaminated rounds. The executor makes
schedule changes and round dispatch near-zero-cost:

* **Schedule as data** — the schedule is a first-class ``[K, 2]`` int32
  device array, not control flow: the K-round superstep scans
  ``(tau1[k], tau2[k])`` as ``lax.scan`` xs alongside the batches, so every
  round of one dispatch can run a DIFFERENT (tau1, tau2)
  (``dispatch_trajectory``; the per-round adaptation of Yan & Li
  arXiv:2308.06496 and the sporadic schedules of DSpodFL arXiv:2402.03448).
  A uniform schedule is just the constant trajectory — ``dispatch(state,
  batches, tau1, tau2)`` broadcasts the pair to [K, 2] and shares the SAME
  compiled executable, so trajectories add zero compiles over PR-3's
  scalar path. Per round, ``round_body`` runs bounded loops over the
  (tau1_max, tau2_max) maxima with dynamic trip counts
  (``make_round_fn(..., dynamic_taus=True)``); any schedule within the
  maxima dispatches against the same executable and a re-plan never
  retraces (asserted via the trace counter below).
* **Fused supersteps** — a jitted ``lax.scan`` over K rounds with the
  ``DFLState`` carry DONATED (params+opt buffers reused in place, halving
  peak state memory vs. the undonated per-round jit) and on-device stacked
  metrics, so the host syncs once per superstep instead of once per round.
  Metrics come back tagged with the REALIZED schedule (``tau1``/``tau2``
  [K] rows), so downstream accounting never has to reconstruct which
  schedule a round actually ran.
* **Overlap** — ``HostPrefetcher`` builds the next superstep's batches on a
  background thread while the device runs, and ``MetricsBuffer`` defers the
  host-blocking metric fetch to log boundaries.
* **Telemetry** — every class here takes an optional ``telemetry=`` sink
  (``repro.obs.Telemetry``) and emits typed events: ``compile`` when a
  superstep traces, ``superstep`` per dispatch, ``prefetch`` build/cancel
  spans from the worker thread, ``flush`` when the buffer syncs. All hooks
  are host-side Python around the jitted calls — they add ZERO ops to the
  round-path HLO and ZERO host syncs (the ``telemetry-neutrality`` audit
  in ``repro.analysis`` proves the instrumented lowering is
  fingerprint-identical to the bare one).

A keyed compile cache (``dynamic=False``) remains as the static fallback for
configs the dynamic path can't express (``mixing_impl='dense_power'``).

Numerics: a dynamic-tau round is bit-identical to the static round in model
state (params / opt_state / hat_params / consensus metric); the scalar loss
METRIC may differ by ~1 ulp because XLA associates the tau1-length and
tau1_max-length loss reductions differently (tests/test_executor.py pins
both properties).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dfl import (DFLConfig, DFLState, make_pipeline_fns,
                            make_round_fn)

PyTree = Any

__all__ = ["RoundExecutor", "HostPrefetcher", "MetricsBuffer",
           "make_pipeline_superstep", "stack_round_batches"]


def stack_round_batches(round_batches: Sequence[PyTree],
                        tau1_max: int) -> PyTree:
    """Stack K per-round batch trees (leaves [tau1, ...]) into superstep
    form (leaves [K, tau1_max, ...]), zero-padding rows >= tau1.

    The padding rows are never read by the dynamic-trip-count loops — they
    only exist so every dispatch shares one compiled shape.
    """
    assert round_batches, "need at least one round of batches"

    def one(*leaves):
        leaves = [np.asarray(x) for x in leaves]
        k = len(leaves)
        tail = leaves[0].shape[1:]
        out = np.zeros((k, tau1_max) + tail, leaves[0].dtype)
        for i, x in enumerate(leaves):
            assert x.shape[0] <= tau1_max, (
                f"round batch has {x.shape[0]} steps > tau1_max={tau1_max}")
            out[i, :x.shape[0]] = x
        return jnp.asarray(out)

    return jax.tree_util.tree_map(one, *round_batches)


def make_pipeline_superstep(pipe_fn, drain_fn, *, participation: bool = False,
                            num_nodes: int = 0, num_edges: int = 0,
                            on_trace: Optional[Callable[[], None]] = None):
    """Fused K-round scan for ``overlap="pipeline"``.

    The scan carry is ``(state, buf, have, prev_tau2[, prev_edge_mask])``:
    ``buf`` holds the previous round's post-local params (the payload of
    the in-flight gossip exchange), ``have`` is 0 only on the first
    iteration (whose exchange runs but folds to a bitwise no-op), and the
    previous row's tau2/edge-mask ride the carry so round k's exchange
    executes — one iteration late — with round k's schedule data. After
    the scan, ``drain_fn`` retires the final in-flight exchange INSIDE the
    same executable, so a dispatched superstep always returns fully-drained
    state (checkpoint/restore never sees gossip in flight).

    ``superstep(state, batches, taus)`` matches the legacy dynamic
    superstep's signature/row layout exactly, so ``dispatch_trajectory``
    and the audits drive both modes identically. ``on_trace`` fires once
    per XLA trace (the executor's compile counter hook).
    """
    def superstep(state: DFLState, batches: PyTree, taus):
        if on_trace is not None:
            on_trace()
        n, e = num_nodes, num_edges
        buf0 = state.params
        have0 = jnp.zeros((), jnp.int32)
        pt2_0 = jnp.zeros((), jnp.int32)
        live = jnp.ones((), jnp.int32)

        if participation:
            def body(carry, xs):
                st, buf, have, pt2, pem = carry
                b, tau = xs
                st, buf, metrics = pipe_fn(st, buf, have, pt2, pem, b,
                                           tau[0], tau[2:2 + n])
                metrics = dict(
                    metrics,
                    active_nodes=jnp.sum(tau[2:2 + n]),
                    masked_edges=jnp.int32(e) - jnp.sum(tau[2 + n:]),
                    tau1=tau[0], tau2=tau[1])
                return (st, buf, live, tau[1], tau[2 + n:]), metrics

            pem0 = jnp.ones((e,), jnp.int32)
            carry0 = (state, buf0, have0, pt2_0, pem0)
            (st, buf, _, pt2, pem), metrics = jax.lax.scan(
                body, carry0, (batches, taus))
            st = drain_fn(st, buf, pt2, pem)
        else:
            def body(carry, xs):
                st, buf, have, pt2 = carry
                b, tau = xs
                st, buf, metrics = pipe_fn(st, buf, have, pt2, b, tau[0])
                return (st, buf, live, tau[1]), dict(
                    metrics, tau1=tau[0], tau2=tau[1])

            carry0 = (state, buf0, have0, pt2_0)
            (st, buf, _, pt2), metrics = jax.lax.scan(
                body, carry0, (batches, taus))
            st = drain_fn(st, buf, pt2)
        return st, metrics

    return superstep


class RoundExecutor:
    """Compile-once dispatch of DFL rounds and K-round supersteps.

    Args:
      cfg: the DFL config whose ``tau1``/``tau2`` are the compiled MAXIMA in
        dynamic mode (any dispatched schedule must satisfy
        1 <= tau1 <= cfg.tau1, 0 <= tau2 <= cfg.tau2) and defaults in static
        mode.
      loss_fn, opt, constrain, engine, mesh, node_axes, use_kernels:
        forwarded to ``core.dfl.make_round_fn``.
      dynamic: True (default) compiles the dynamic-tau round once; False is
        the keyed static fallback — one compile per distinct (tau1, tau2),
        cached.
      participation: widen the schedule rows to ``[K, 2 + N + E]`` — per
        round, (tau1, tau2) followed by an [N] 0/1 node-participation mask
        and an [E] 0/1 edge mask over ``cfg.topology.edges()`` — and run
        the sporadic round semantic (``round_body(..., masks=...)``).
        Plain [K, 2] trajectories are auto-padded with all-ones masks (and
        stay bitwise the unmasked rounds). Dynamic mode only: masks are
        schedule DATA scanned as xs, so heterogeneous participation shares
        the one compiled superstep (zero recompiles, audited).
      donate: donate the DFLState argument of every dispatch (the caller
        must treat the passed-in state as consumed).
      overlap: ``"none"`` (default) keeps the legacy superstep — the code
        path is untouched, so it is BITWISE the pre-overlap executor
        (asserted in tests/test_overlap.py). ``"pipeline"`` double-buffers
        the scan: round k's tau2 gossip exchange is issued alongside round
        k+1's tau1 local updates and folded one round late (one-round-stale
        mixing; see ``core.dfl.pipeline_round_body``), with the final
        exchange drained inside the same executable so dispatch boundaries
        never hold gossip in flight. Dynamic mode only. The planner prices
        the mode via ``CostModel(overlap=...)`` and
        ``bounds.stale_mixing_zeta``.
      telemetry: optional ``repro.obs.Telemetry`` sink; dispatches emit
        ``superstep`` events and traces emit ``compile`` events on the
        "dispatch" track. Host-side only — never traced into the HLO.

    ``dispatch(state, batches, tau1, tau2)`` runs one superstep: batches
    leaves are [K, tau1_max, ...] (dynamic) / [K, tau1, ...]-compatible
    (static mode slices the padded rows off), K inferred from the leading
    dim; returns ``(state', metrics)`` with metrics leaves stacked [K]
    (including the realized ``tau1``/``tau2`` per round).
    ``dispatch_trajectory(state, batches, taus)`` is the general form:
    ``taus`` is a [K, 2] int32 array and round k runs
    (taus[k, 0], taus[k, 1]) — scanned as xs through the SAME executable
    the uniform dispatch uses, so heterogeneous schedules cost zero extra
    compiles. ``compile_count`` counts traces of the superstep — the
    zero-recompile assertion hook for tests and benchmarks.
    """

    def __init__(
        self,
        cfg: DFLConfig,
        loss_fn,
        opt,
        *,
        constrain=None,
        engine: str = "dense",
        mesh=None,
        node_axes: Sequence[str] = ("data",),
        use_kernels: bool = False,
        dynamic: bool = True,
        participation: bool = False,
        donate: bool = True,
        telemetry=None,
        overlap: str = "none",
        population: Optional[int] = None,
    ):
        self.cfg = cfg
        self.dynamic = dynamic
        self.donate = donate
        self.num_nodes = cfg.topology.num_nodes
        self.num_edges = cfg.topology.num_edges
        if engine == "auto" and population is not None:
            engine = "batched"
        self.batched = engine == "batched"
        if self.batched:
            if population is None:
                raise ValueError(
                    "engine='batched' needs population=V (virtual node "
                    "count the state leaves are stacked over)")
            if not dynamic:
                raise ValueError(
                    "cohort ids are schedule data on the dynamic path; "
                    "the static fallback keys compiles on (tau1, tau2) "
                    "and cannot express per-round cohorts")
            # cohort rows subsume the participation layout (ids + masks).
            participation = True
        elif population is not None:
            raise ValueError(
                f"population= is a batched-engine parameter (got engine="
                f"{engine!r})")
        self.population = population
        self.participation = participation
        if overlap not in ("none", "pipeline"):
            raise ValueError(
                f"unknown overlap mode {overlap!r} (use 'none'|'pipeline')")
        if overlap == "pipeline" and not dynamic:
            raise ValueError(
                "overlap='pipeline' rides the dynamic superstep scan; the "
                "static fallback has no carry to double-buffer "
                "(pass dynamic=True)")
        if overlap == "pipeline" and self.batched:
            raise ValueError(
                "overlap='pipeline' is not supported on the batched "
                "engine: consecutive rounds gossip over DIFFERENT sampled "
                "cohorts (use overlap='none')")
        self.overlap = overlap
        if participation and not dynamic:
            raise ValueError(
                "participation masks are schedule data on the dynamic "
                "path; the static fallback keys compiles on (tau1, tau2) "
                "and cannot express per-round masks")
        self._make_kw = dict(
            constrain=constrain, engine=engine, mesh=mesh,
            node_axes=tuple(node_axes), use_kernels=use_kernels)
        self._loss_fn = loss_fn
        self._opt = opt
        self._tel = telemetry
        self._trace_count = 0
        self.dispatch_count = 0
        self.rounds_dispatched = 0
        self._in_warmup = False
        self._static_cache: Dict[Tuple[int, int], Callable] = {}
        # host-work memo for the dispatch hot path: validated/padded
        # trajectory rows + their device array, keyed on the raw bytes
        # (the adaptive controller re-emits unchanged chunks often, and
        # uniform dispatches always hit after warmup).
        self._traj_cache: Dict[Any, Tuple[np.ndarray, Any]] = {}
        if dynamic and overlap == "pipeline":
            pipe_fn, drain_fn = make_pipeline_fns(
                cfg, loss_fn, opt, participation=participation,
                **self._make_kw)

            def _traced():
                self._trace_count += 1  # fires per trace == per compile
                self._note_trace("pipeline")

            superstep = make_pipeline_superstep(
                pipe_fn, drain_fn, participation=participation,
                num_nodes=self.num_nodes, num_edges=self.num_edges,
                on_trace=_traced)
            self._dynamic_fn = jax.jit(
                superstep, donate_argnums=(0,) if donate else ())
        elif dynamic:
            round_fn = make_round_fn(cfg, loss_fn, opt, dynamic_taus=True,
                                     participation=(participation
                                                    and not self.batched),
                                     population=population,
                                     **self._make_kw)
            n, e = self.num_nodes, self.num_edges
            batched = self.batched

            def superstep(state: DFLState, batches: PyTree, taus):
                self._trace_count += 1  # fires per trace == per compile
                self._note_trace("dynamic")

                def body(st, xs):
                    b, tau = xs
                    if batched:
                        # cohort row layout: (tau1, tau2, ids [C],
                        # node mask [C], edge mask [E]) — ids and masks
                        # are schedule DATA, so every cohort draw rides
                        # the one compiled superstep (cohort-recompile
                        # audit).
                        nm = tau[2 + n:2 + 2 * n]
                        st, metrics = round_fn(
                            st, b, tau[0], tau[1], tau[2:2 + n],
                            nm, tau[2 + 2 * n:])
                        metrics = dict(
                            metrics,
                            active_nodes=jnp.sum(nm),
                            masked_edges=(jnp.int32(e)
                                          - jnp.sum(tau[2 + 2 * n:])))
                    elif participation:
                        st, metrics = round_fn(
                            st, b, tau[0], tau[1],
                            tau[2:2 + n], tau[2 + n:])
                        # realized participation alongside the realized
                        # schedule: what each round ACTUALLY ran.
                        metrics = dict(
                            metrics,
                            active_nodes=jnp.sum(tau[2:2 + n]),
                            masked_edges=(jnp.int32(e)
                                          - jnp.sum(tau[2 + n:])))
                    else:
                        st, metrics = round_fn(st, b, tau[0], tau[1])
                    # tag metrics with the REALIZED schedule so per-round
                    # accounting survives heterogeneous trajectories.
                    return st, dict(metrics, tau1=tau[0], tau2=tau[1])

                return jax.lax.scan(body, state, (batches, taus))

            self._dynamic_fn = jax.jit(
                superstep, donate_argnums=(0,) if donate else ())

    # -- telemetry ---------------------------------------------------------

    def _note_trace(self, kind: str) -> None:
        """Record one XLA trace of a superstep. Runs at TRACE time on the
        host (a Python side-effect of the traced closure, like the counter
        itself) — it inserts nothing into the jaxpr, so the lowered HLO is
        identical with or without a sink (audited)."""
        if self._tel is not None:
            self._tel.emit("compile", track="dispatch",
                           name=f"superstep-trace-{kind}",
                           count=self._trace_count)

    # -- properties --------------------------------------------------------

    @property
    def tau1_max(self) -> int:
        return self.cfg.tau1

    @property
    def tau2_max(self) -> int:
        return self.cfg.tau2

    @property
    def compile_count(self) -> int:
        """Number of XLA compilations this executor has triggered (a jit
        cache hit does not retrace, so a steady count across re-plans IS the
        recompile-free property)."""
        return self._trace_count

    # -- audit hook --------------------------------------------------------

    def lower_superstep(self, state: DFLState, batches: PyTree, taus):
        """Lower (without compiling) the dynamic superstep at example
        arguments — the compiled-artifact audit hook
        (``repro.analysis.audits``): donation is read off the compiled
        module's ``input_output_alias`` header, recompile hazards by
        fingerprinting lowerings at different trajectory values,
        collective matching off the optimized HLO's permute pairs.
        Audit lowerings do not touch ``compile_count`` (the
        zero-recompile assertions only count *dispatch* traces). A
        ``telemetry`` sink stays LIVE through the lowering on purpose:
        the ``telemetry-neutrality`` audit compares instrumented vs bare
        lowerings, so the instrumented trace must actually run its hooks.
        Dynamic mode only — the static fallback intentionally keys
        compiles on (tau1, tau2)."""
        if not self.dynamic:
            raise ValueError(
                "lower_superstep needs dynamic=True: the static fallback "
                "bakes (tau1, tau2) per compile by design")
        n = self._trace_count
        try:
            return self._dynamic_fn.lower(
                state, batches, jnp.asarray(taus, jnp.int32))
        finally:
            self._trace_count = n

    # -- dispatch ----------------------------------------------------------

    def _check_taus(self, tau1: int, tau2: int) -> Tuple[int, int]:
        tau1, tau2 = int(tau1), int(tau2)
        if not 1 <= tau1 <= self.tau1_max:
            raise ValueError(
                f"tau1={tau1} outside compiled bounds [1, {self.tau1_max}]; "
                "rebuild the executor with a larger tau1_max")
        if not 0 <= tau2 <= self.tau2_max:
            raise ValueError(
                f"tau2={tau2} outside compiled bounds [0, {self.tau2_max}]; "
                "rebuild the executor with a larger tau2_max")
        return tau1, tau2

    @property
    def row_width(self) -> int:
        """Trajectory row width: 2; 2 + N + E with participation; or
        2 + 2C + E on the batched engine (tau1, tau2, cohort ids [C],
        node mask [C], edge mask [E])."""
        if self.batched:
            return 2 + 2 * self.num_nodes + self.num_edges
        if self.participation:
            return 2 + self.num_nodes + self.num_edges
        return 2

    def _check_trajectory(self, taus, k: int) -> np.ndarray:
        arr = np.asarray(taus, dtype=np.int32)
        if self.batched:
            c = self.num_nodes
            if arr.ndim != 2 or arr.shape[1] not in (2, self.row_width):
                raise ValueError(
                    f"cohort trajectory must be [K, 2] (identity cohort, "
                    f"all-active) or [K, {self.row_width}] (tau1, tau2, "
                    f"cohort ids [{c}], node mask [{c}], edge mask "
                    f"[{self.num_edges}]) rows, got shape {arr.shape}")
            if arr.shape[1] == 2:  # plain schedule: identity cohort
                kk = arr.shape[0]
                arr = np.concatenate(
                    [arr,
                     np.broadcast_to(np.arange(c, dtype=np.int32), (kk, c)),
                     np.ones((kk, self.row_width - 2 - c), np.int32)],
                    axis=1)
            ids = arr[:, 2:2 + c]
            if ids.size:
                if ids.min() < 0 or ids.max() >= self.population:
                    raise ValueError(
                        f"cohort ids must lie in [0, {self.population}) "
                        f"(got range [{ids.min()}, {ids.max()}])")
                if any(len(np.unique(row)) != c for row in ids):
                    raise ValueError(
                        "cohort ids must be unique within each row "
                        "(a node cannot occupy two cohort slots)")
            masks = arr[:, 2 + c:]
            if masks.size and not np.isin(masks, (0, 1)).all():
                raise ValueError(
                    "participation masks must be 0/1 "
                    f"(got values {sorted(set(masks.ravel().tolist()))})")
        elif self.participation:
            if arr.ndim != 2 or arr.shape[1] not in (2, self.row_width):
                raise ValueError(
                    f"participation trajectory must be [K, 2] (all-active) "
                    f"or [K, {self.row_width}] (tau1, tau2, node mask "
                    f"[{self.num_nodes}], edge mask [{self.num_edges}]) "
                    f"rows, got shape {arr.shape}")
            if arr.shape[1] == 2:  # plain schedule: everyone participates
                arr = np.concatenate(
                    [arr, np.ones((arr.shape[0], self.row_width - 2),
                                  np.int32)], axis=1)
            masks = arr[:, 2:]
            if masks.size and not np.isin(masks, (0, 1)).all():
                raise ValueError(
                    "participation masks must be 0/1 "
                    f"(got values {sorted(set(masks.ravel().tolist()))})")
        elif arr.ndim != 2 or arr.shape[1] != 2:
            raise ValueError(
                f"trajectory must be [K, 2] (tau1, tau2) rows, got shape "
                f"{arr.shape}")
        if arr.shape[0] != k:
            raise ValueError(
                f"trajectory has {arr.shape[0]} rows but batches carry "
                f"K={k} rounds")
        for t1 in (int(arr[:, 0].min()), int(arr[:, 0].max())):
            if not 1 <= t1 <= self.tau1_max:
                raise ValueError(
                    f"tau1={t1} outside compiled bounds [1, {self.tau1_max}]"
                    "; rebuild the executor with a larger tau1_max")
        for t2 in (int(arr[:, 1].min()), int(arr[:, 1].max())):
            if not 0 <= t2 <= self.tau2_max:
                raise ValueError(
                    f"tau2={t2} outside compiled bounds [0, {self.tau2_max}]"
                    "; rebuild the executor with a larger tau2_max")
        return arr

    def _static_fn(self, tau1: int, tau2: int) -> Callable:
        key = (tau1, tau2)
        fn = self._static_cache.get(key)
        if fn is None:
            import dataclasses

            cfg = dataclasses.replace(self.cfg, tau1=tau1, tau2=tau2)
            round_fn = make_round_fn(cfg, self._loss_fn, self._opt,
                                     **self._make_kw)

            def superstep(state: DFLState, batches: PyTree):
                self._trace_count += 1
                self._note_trace("static")

                return jax.lax.scan(round_fn, state, batches)

            fn = jax.jit(superstep,
                         donate_argnums=(0,) if self.donate else ())
            self._static_cache[key] = fn
        return fn

    _TRAJ_CACHE_MAX = 128

    def _prepare_trajectory(self, key, build) -> Tuple[np.ndarray, Any]:
        """Memoized validation + padding + device transfer of a trajectory.

        ``_check_trajectory``'s numpy validation, the participation-mode
        all-ones mask padding, and the host->device ``jnp.asarray`` upload
        together dominate the CPU dispatch floor on micro models (ROADMAP:
        superstep K=1 was ~20% slower than a static jit). The adaptive
        controller re-emits unchanged chunks often and uniform dispatches
        repeat (k, tau1, tau2) forever, so both are keyed here — content
        bytes for explicit trajectories, the scalar triple for uniform
        ones — and repeated identical dispatches skip the host work
        entirely. Bounded FIFO so pathological schedule churn can't grow
        host memory."""
        hit = self._traj_cache.get(key)
        if hit is None:
            arr = build()
            # never alias caller memory: the cache key is content bytes,
            # so an in-place caller mutation must not retro-edit the entry.
            arr = arr.copy()
            dev = jnp.asarray(arr) if self.dynamic else None
            if len(self._traj_cache) >= self._TRAJ_CACHE_MAX:
                self._traj_cache.pop(next(iter(self._traj_cache)))
            self._traj_cache[key] = hit = (arr, dev)
        return hit

    def dispatch_trajectory(self, state: DFLState, batches: PyTree,
                            taus) -> Tuple[DFLState, dict]:
        """One fused superstep executing a heterogeneous schedule: round k
        runs (taus[k, 0], taus[k, 1]) local/gossip steps. ``taus`` is a
        [K, 2] int-like array with every row inside the compiled
        (tau1_max, tau2_max) bounds; batches leaves are [K, tau1_max, ...]
        (only the first taus[k, 0] rows of round k are read). In dynamic
        mode the trajectory rides the SAME executable as the uniform
        ``dispatch`` — schedule heterogeneity never compiles. The static
        fallback splits the trajectory into contiguous uniform segments and
        plays them through the keyed compile cache (one compile per
        distinct (tau1, tau2), as always). Returned metrics are stacked [K]
        and tagged with the realized per-round ``tau1``/``tau2``.

        Validation and the schedule's device upload are memoized on the
        trajectory's content (``_prepare_trajectory``), so re-dispatching
        an unchanged chunk costs no host-side re-checking."""
        k = jax.tree_util.tree_leaves(batches)[0].shape[0]
        raw = np.asarray(taus, dtype=np.int32)
        arr, dev = self._prepare_trajectory(
            (k, raw.shape, raw.tobytes()),
            lambda: self._check_trajectory(raw, k))
        return self._dispatch_prepared(state, batches, arr, dev, k)

    def _dispatch_prepared(self, state: DFLState, batches: PyTree,
                           arr: np.ndarray, dev, k: int):
        self.dispatch_count += 1
        self.rounds_dispatched += k
        if self._tel is None:
            return self._run_trajectory(state, batches, arr, dev, k)
        t0 = self._tel.now()
        out = self._run_trajectory(state, batches, arr, dev, k)
        # On sync backends (this jaxlib's CPU client) the superstep
        # EXECUTES inside the call, so dur is real device time; on async
        # backends it is enqueue cost and the flush event carries the rest.
        # Warmup dispatches are tagged apart so reports never conflate
        # compile-warming with measured supersteps.
        dur = self._tel.now() - t0
        prefix = "warmup-superstep" if self._in_warmup else "superstep"
        self._tel.emit("superstep", track="dispatch", name=f"{prefix}-k{k}",
                       t=t0, dur=dur, k=k,
                       warmup=self._in_warmup, dispatch=self.dispatch_count)
        if self.overlap == "pipeline" and not self._in_warmup:
            # the gossip slice riding under the compute slice: the stale
            # exchange of rounds [0, k) is in flight INSIDE this dispatch
            # window (drained before it returns), so the overlap track
            # mirrors the superstep span one level down.
            self._tel.emit("overlap", track="overlap",
                           name=f"gossip-inflight-k{k}", t=t0, dur=dur,
                           mode=self.overlap, k=k,
                           dispatch=self.dispatch_count)
        return out

    def _run_trajectory(self, state: DFLState, batches: PyTree,
                        arr: np.ndarray, dev, k: int
                        ) -> Tuple[DFLState, dict]:
        if self.dynamic:
            return self._dynamic_fn(
                state, batches, dev if dev is not None else jnp.asarray(arr))
        # static fallback: contiguous uniform segments, padding rows
        # (which the dynamic layout carries) sliced off per segment.
        parts: List[dict] = []
        i = 0
        while i < k:
            j = i + 1
            while j < k and (arr[j] == arr[i]).all():
                j += 1
            t1, t2 = int(arr[i, 0]), int(arr[i, 1])
            seg = jax.tree_util.tree_map(lambda b: b[i:j, :t1], batches)
            state, m = self._static_fn(t1, t2)(state, seg)
            parts.append(dict(
                m,
                tau1=jnp.full((j - i,), t1, jnp.int32),
                tau2=jnp.full((j - i,), t2, jnp.int32)))
            i = j
        metrics = {key: (parts[0][key] if len(parts) == 1
                         else jnp.concatenate([p[key] for p in parts]))
                   for key in parts[0]}
        return state, metrics

    def dispatch(self, state: DFLState, batches: PyTree, tau1: int,
                 tau2: int) -> Tuple[DFLState, dict]:
        """One K-round fused superstep (K = batches' leading dim) at a
        uniform (tau1, tau2): the constant-trajectory special case. The
        broadcast [K, 2] schedule (plus its validation and device upload)
        is memoized on (k, tau1, tau2) — the steady-state uniform dispatch
        does no per-call host schedule work at all."""
        tau1, tau2 = self._check_taus(tau1, tau2)
        k = jax.tree_util.tree_leaves(batches)[0].shape[0]
        arr, dev = self._prepare_trajectory(
            ("uniform", k, tau1, tau2),
            lambda: self._check_trajectory(
                # repro-lint: disable=no-host-coercion-of-device-scalars (dispatch's taus are host ints by API contract — _check_taus already coerced them; this builds the broadcast schedule, it reads no device value)
                np.tile(np.array([[tau1, tau2]], np.int32), (k, 1)), k))
        return self._dispatch_prepared(state, batches, arr, dev, k)

    def dispatch_round(self, state: DFLState, batches: PyTree, tau1: int,
                       tau2: int) -> Tuple[DFLState, dict]:
        """Single-round convenience: batches leaves [tau1_max, ...];
        returns per-round (unstacked) metrics."""
        add_k = lambda t: jax.tree_util.tree_map(lambda x: x[None], t)
        state, metrics = self.dispatch(state, add_k(batches), tau1, tau2)
        return state, jax.tree_util.tree_map(lambda m: m[0], metrics)

    def warmup(self, state: DFLState, batches: PyTree,
               tau1: int = 1, tau2: int = 0) -> None:
        """Pay the trace+compile for this batch SHAPE (and, in static mode,
        this (tau1, tau2) key) before any measured dispatch, on a throwaway
        copy of ``state`` (donation consumes it) — on this jaxlib the CPU
        client executes synchronously inside ``dispatch``, so a compile
        occurring there would otherwise contaminate the measured window of
        whatever round runs first at that shape (AOT ``lower().compile()``
        does not populate the jit call cache on the 0.4.37 pin, hence a
        real dummy dispatch). Dynamic mode compiles one executable per
        shape, so the default minimal schedule (1, 0) is enough; static
        mode must warm every (tau1, tau2) it will dispatch. Dispatch
        statistics are left untouched."""
        dummy = jax.tree_util.tree_map(jnp.copy, state)
        n_dispatch, n_rounds = self.dispatch_count, self.rounds_dispatched
        self._in_warmup = True
        try:
            if self._tel is not None:
                with self._tel.span("warmup", track="dispatch"):
                    out = self.dispatch(dummy, batches, tau1, tau2)
                    jax.block_until_ready(out)
            else:
                out = self.dispatch(dummy, batches, tau1, tau2)
                jax.block_until_ready(out)
        finally:
            self._in_warmup = False
        self.dispatch_count, self.rounds_dispatched = n_dispatch, n_rounds


class HostPrefetcher:
    """Double-buffered host batch prefetch.

    ``schedule(fn, *args, meta=...)`` starts building the NEXT superstep's
    batches on a daemon thread while the device executes the current one;
    ``take()`` joins and returns ``(result, meta)``. The ``meta`` tag (e.g.
    ``(round0, k, tau1)``) lets the caller detect a stale prefetch after a
    re-plan changed the schedule and rebuild inline — re-plans are rare, so
    at most one chunk is ever discarded.

    Failure paths are hard errors, not asserts (they survive ``-O``):
    double-``schedule`` and ``take`` without a schedule raise
    ``RuntimeError``; a worker exception is re-raised on ``take``.

    ``retries``: transient batch-build ``Exception``s are retried on the
    worker thread up to ``retries`` extra attempts with exponential
    backoff (``backoff_s``, doubling per attempt) before the LAST error is
    parked for ``take()`` to re-raise — a flaky data source degrades a
    prefetch to slower instead of killing the run. Non-``Exception``
    ``BaseException``s (KeyboardInterrupt, SystemExit) are never retried.
    ``close()`` is the clean-shutdown path: it stops any backoff wait,
    joins the pending worker (no thread leak on teardown), and drops its
    result/error; the prefetcher refuses new ``schedule`` calls after.

    ``stats`` counts scheduled/taken/cancelled/stale/errors/retries; with
    a ``telemetry`` sink the WORKER thread emits a ``prefetch`` build span
    (so host batch construction shows as its own track in the timeline)
    and cancels/stales/retries emit instants.
    """

    def __init__(self, telemetry=None, retries: int = 0,
                 backoff_s: float = 0.05):
        assert retries >= 0 and backoff_s >= 0.0
        self._pending: Optional[Tuple[threading.Thread, dict, Any]] = None
        self._tel = telemetry
        self._retries = int(retries)
        self._backoff_s = float(backoff_s)
        self._stop = threading.Event()
        self.stats: Dict[str, int] = {
            "scheduled": 0, "taken": 0, "cancelled": 0, "stale": 0,
            "errors": 0, "retries": 0}

    def schedule(self, fn: Callable, *args, meta: Any = None) -> None:
        if self._stop.is_set():
            raise RuntimeError("prefetcher closed — no further schedules")
        if self._pending is not None:
            raise RuntimeError(
                "previous prefetch not taken — call take() or cancel() "
                "before scheduling another build")
        self.stats["scheduled"] += 1
        box: dict = {}
        tel = self._tel

        def work():
            t0 = tel.now() if tel is not None else 0.0
            try:
                for attempt in range(self._retries + 1):
                    try:
                        box["out"] = fn(*args)
                        box.pop("err", None)
                        return
                    except BaseException as e:  # re-raised on take()
                        box["err"] = e
                        if (attempt >= self._retries
                                or not isinstance(e, Exception)):
                            return
                        self.stats["retries"] += 1
                        if tel is not None:
                            tel.emit("prefetch", track="prefetch",
                                     name="retry", action="retry",
                                     attempt=attempt + 1)
                        # interruptible backoff: close() wakes it
                        if self._stop.wait(self._backoff_s * (2 ** attempt)):
                            return
            finally:
                if tel is not None:
                    tel.emit("prefetch", track="prefetch", name="build",
                             t=t0, dur=tel.now() - t0, action="build",
                             ok="err" not in box)

        t = threading.Thread(target=work, daemon=True)
        t.start()
        self._pending = (t, box, meta)

    @property
    def pending_meta(self) -> Any:
        return self._pending[2] if self._pending is not None else None

    def take(self) -> Tuple[Any, Any]:
        if self._pending is None:
            raise RuntimeError("nothing scheduled — call schedule() first")
        t, box, meta = self._pending
        self._pending = None
        t.join()
        if "err" in box:
            self.stats["errors"] += 1
            raise box["err"]
        self.stats["taken"] += 1
        return box["out"], meta

    def cancel(self) -> None:
        """Discard a stale prefetch (joins the worker; a build error in
        data that will never be used is dropped, not re-raised)."""
        if self._pending is None:
            return
        t, box, _meta = self._pending
        self._pending = None
        t.join()
        self.stats["cancelled"] += 1
        if self._tel is not None:
            self._tel.emit("prefetch", track="prefetch", name="cancel",
                           action="cancel")

    def mark_stale(self) -> None:
        """Caller-noted stale take: the prefetched chunk was rebuilt
        because a re-plan changed the schedule after it was scheduled
        (counts toward the hit/stale attribution in run reports)."""
        self.stats["stale"] += 1
        if self._tel is not None:
            self._tel.emit("prefetch", track="prefetch", name="stale",
                           action="stale")

    def close(self) -> None:
        """Clean shutdown: wake any backoff wait, join the pending worker
        thread, and discard its result or parked error. Idempotent; the
        prefetcher rejects ``schedule`` afterwards. Call on every exit
        path (success, exception, signal teardown) so a failed build can
        never leak its thread past the run."""
        already = self._stop.is_set()
        self._stop.set()
        if self._pending is not None:
            t, _box, _meta = self._pending
            self._pending = None
            t.join()
            self.stats["cancelled"] += 1
        if self._tel is not None and not already:
            self._tel.emit("prefetch", track="prefetch", name="close",
                           action="close")


class MetricsBuffer:
    """On-device stacked round metrics, host-materialized only on flush.

    ``push`` records a dispatched superstep's device metrics WITHOUT
    blocking; ``flush`` calls ``jax.block_until_ready`` once (at a log /
    checkpoint / re-plan boundary), converts to per-round host rows, and
    amortizes the measured wall-clock since the window opened over the
    rounds it covered (per-round dispatch would instead pay one sync per
    round).

    ``dispatched_at``: pass ``time.perf_counter()`` taken BEFORE the
    dispatch call. On synchronous backends (this jaxlib's CPU client) the
    superstep EXECUTES inside ``dispatch``, so a window opened at push time
    would measure ~zero; the pre-dispatch stamp of the window's first chunk
    is the correct wall-clock origin on sync and async backends both. It
    also means a compile occurring inside a dispatch lands in that window —
    warm every batch shape up front (see ``launch.train``) so measured
    rounds stay compile-free.

    All window arithmetic is on the MONOTONIC ``perf_counter`` clock: a
    wall-clock jump (NTP step, DST) must never corrupt ``round_s``, which
    feeds the ``AdaptiveController`` least-squares cost fit. Absolute
    timestamps exist only in telemetry ``run`` headers.

    With a ``telemetry`` sink, ``flush`` emits a ``flush`` event spanning
    the host-blocking ``block_until_ready`` (the metrics track shows
    exactly when — and for how long — the host actually synced).
    """

    def __init__(self, telemetry=None):
        self._pending: List[Tuple[int, int, int, int, dict]] = []
        self._window_start: Optional[float] = None
        self._tel = telemetry

    def push(self, round0: int, k: int, tau1: Optional[int],
             tau2: Optional[int], metrics: dict,
             dispatched_at: Optional[float] = None) -> None:
        """``tau1``/``tau2`` may be None when the metrics carry the
        realized per-round ``tau1``/``tau2`` rows (executor dispatches tag
        them); metric-carried values win over the scalars either way, so
        heterogeneous-trajectory supersteps report the schedule each round
        actually ran."""
        if self._window_start is None:
            self._window_start = (dispatched_at if dispatched_at is not None
                                  else time.perf_counter())
        self._pending.append((round0, k, tau1, tau2, metrics))

    @property
    def pending_rounds(self) -> int:
        return sum(k for _, k, _, _, _ in self._pending)

    def flush(self) -> List[dict]:
        """Block once; return one row per completed round, in order."""
        if not self._pending:
            return []
        block0 = time.perf_counter()
        jax.block_until_ready([m for *_, m in self._pending])
        now = time.perf_counter()
        elapsed = now - (self._window_start or now)
        n = self.pending_rounds
        if self._tel is not None:
            block_s = now - block0
            self._tel.emit("flush", track="metrics", name="metrics-flush",
                           t=self._tel.now() - block_s, dur=block_s,
                           rounds=n, window_s=elapsed)
        per_round_s = elapsed / max(n, 1)
        rows: List[dict] = []
        int_cols = ("active_nodes", "masked_edges")
        for round0, k, tau1, tau2, metrics in self._pending:
            host = {key: np.asarray(v) for key, v in metrics.items()}
            tau1s = host.pop("tau1", None)
            tau2s = host.pop("tau2", None)
            for i in range(k):
                row = {key: (int(v[i]) if key in int_cols else float(v[i]))
                       for key, v in host.items()}
                row.update(
                    round=round0 + i,
                    tau1=int(tau1s[i]) if tau1s is not None else tau1,
                    tau2=int(tau2s[i]) if tau2s is not None else tau2,
                    round_s=per_round_s)
                rows.append(row)
        self._pending = []
        self._window_start = None
        return rows
