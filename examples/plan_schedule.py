"""Plan -> train -> report: the resource-constrained planner end-to-end.

Eight nodes on a ring, a wall-clock budget, and a grid of (tau1, tau2)
schedules: the planner picks the schedule minimizing the Proposition-1
objective under the budget, Algorithm 1 runs it (analytic quadratic
testbed, so every constant is exact), and the report compares the
planner's predicted cost/quality against what the run actually measured —
for the planned schedule AND every rejected grid point.

    PYTHONPATH=src python examples/plan_schedule.py
    PYTHONPATH=src python examples/plan_schedule.py --smoke --json out.json
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.theory_check import run_dfl_quadratic
from repro.core.topology import ring
from repro.planner import Budget, evaluate_grid, select_plan, unit_cost_model

N = 8
DIM = 16
SIGMA = 0.5        # sampling noise
TSCALE = 0.8       # heterogeneity (non-IID target spread)
GRID = [(1, 4), (1, 2), (2, 2), (2, 1), (4, 1), (8, 1)]
RATIOS = (0.2, 25.0)       # t_gossip / t_compute regimes to plan for
REF_ROUNDS = 60            # budget = 60 rounds of the (2, 2) schedule


def testbed_constants(topo):
    rng = np.random.default_rng(0)
    targets = rng.normal(size=(topo.num_nodes, DIM)) * TSCALE
    tbar = targets.mean(0)
    f_gap = 0.5 * float(np.sum(tbar**2))
    sigma_eff = np.sqrt(
        SIGMA**2 + float(np.max(np.sum((targets - tbar) ** 2, axis=1))))
    return f_gap, sigma_eff


def measured(eta, tau1, tau2, topo, rounds, seeds):
    return float(np.mean([
        run_dfl_quadratic(eta, tau1, tau2, topo, rounds, d=DIM, sigma=SIGMA,
                          seed=s, target_scale=TSCALE)[0]
        for s in range(seeds)]))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="2-point sweep with 1 seed (CI artifact job)")
    ap.add_argument("--json", default="", help="write the report here")
    args = ap.parse_args()
    seeds = 1 if args.smoke else 4
    grid = GRID[:3] + GRID[-1:] if args.smoke else GRID

    topo = ring(N)
    f_gap, sigma_eff = testbed_constants(topo)
    report = {"nodes": N, "zeta": topo.zeta, "grid": grid, "regimes": []}
    print(f"{N}-node ring (zeta={topo.zeta:.3f}), budget = {REF_ROUNDS} "
          f"reference rounds, sigma_eff={sigma_eff:.2f}\n")
    for ratio in RATIOS:
        cost_model = unit_cost_model(topo, ratio)
        budget = Budget(
            wall_clock_s=cost_model.round_cost(2, 2).time_s * REF_ROUNDS)
        cands = evaluate_grid(budget, cost_model, sigma=sigma_eff,
                              f_gap=f_gap, grid=grid)
        p = select_plan(cands)
        rows = []
        for cand in cands:
            m = measured(cand.eta, cand.tau1, cand.tau2, topo, cand.rounds,
                         seeds)
            rows.append({
                "tau1": cand.tau1, "tau2": cand.tau2,
                "rounds_in_budget": cand.rounds,
                "eta": round(cand.eta, 5),
                "predicted": round(cand.predicted_bound, 5),
                "measured": round(m, 5),
                "planned": (cand.tau1, cand.tau2) == (p.tau1, p.tau2),
            })
        rows.sort(key=lambda r: r["measured"])
        report["regimes"].append({
            "comm_compute_ratio": ratio,
            "budget_s": budget.wall_clock_s,
            "planned": {"tau1": p.tau1, "tau2": p.tau2,
                        "predicted_bound": p.predicted_bound},
            "table": rows,
        })
        print(f"comm/compute ratio {ratio}: planned tau=({p.tau1},{p.tau2})")
        print(f"  {'tau':>8s} {'rounds':>7s} {'eta':>8s} "
              f"{'predicted':>10s} {'measured':>9s}")
        for r in rows:
            mark = " <- planned" if r["planned"] else ""
            print(f"  ({r['tau1']},{r['tau2']}){'':>3s} "
                  f"{r['rounds_in_budget']:>7d} {r['eta']:>8.4f} "
                  f"{r['predicted']:>10.4f} {r['measured']:>9.5f}{mark}")
        best = rows[0]
        print(f"  measured best: ({best['tau1']},{best['tau2']}) — planner "
              f"{'agrees' if best['planned'] else 'close (bound-argmin)'}\n")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
        print(f"report -> {args.json}")


if __name__ == "__main__":
    main()
