"""Quickstart: DFL in ~60 lines.

Ten nodes on a ring learn a shared linear model from non-IID data with
tau1 local SGD steps and tau2 gossip steps per round — the paper's
Algorithm 1 — then the same problem with compressed gossip (C-DFL, Alg. 2).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import (DFLConfig, average_model, init_state, make_compressor,
                        make_round_fn, ring)
from repro.optim import sgd

N = 10                       # nodes (paper Sec. VI-A)
DIM = 32
KEY = jax.random.key(0)

# --- non-IID linear regression: each node sees a biased slice -------------
true_w = jax.random.normal(jax.random.fold_in(KEY, 1), (DIM,))
node_bias = jnp.linspace(-1.0, 1.0, N)


def make_batches(key, tau1, batch=16):
    kx, kn = jax.random.split(key)
    x = jax.random.normal(kx, (tau1, N, batch, DIM))
    x = x + node_bias[None, :, None, None]          # feature shift per node
    y = x @ true_w + 0.05 * jax.random.normal(kn, (tau1, N, batch))
    return {"x": x, "y": y}


def loss_fn(params, batch, key=None):
    pred = batch["x"] @ params["w"]
    return jnp.mean((pred - batch["y"]) ** 2)


def train(cfg, rounds=60, label=""):
    opt = sgd(0.01)
    state = init_state({"w": jnp.zeros((DIM,))}, N, opt,
                       jax.random.key(1), compressed=cfg.is_compressed)
    round_fn = jax.jit(make_round_fn(cfg, loss_fn, opt))
    key = jax.random.key(2)
    for r in range(rounds):
        key, sub = jax.random.split(key)
        state, metrics = round_fn(state, make_batches(sub, cfg.tau1))
    avg = average_model(state.params)
    err = float(jnp.linalg.norm(avg["w"] - true_w))
    print(f"{label:28s} loss={float(metrics['loss']):.4f} "
          f"consensus={float(metrics['consensus_sq']):.2e} "
          f"|w-w*|={err:.4f}")
    return err


print(f"{N}-node ring, zeta={ring(N).zeta:.3f}\n")
train(DFLConfig(tau1=4, tau2=1, topology=ring(N)), label="C-SGD (tau2=1)")
train(DFLConfig(tau1=4, tau2=4, topology=ring(N)), label="DFL   (tau2=4)")
train(DFLConfig(tau1=4, tau2=4, topology=ring(N),
                compression=make_compressor("qsgd"), gamma=0.5),
      label="C-DFL (qsgd)")
