"""End-to-end driver: DFL-train a ~100M-parameter qwen3-style LM for a few
hundred rounds on the synthetic non-IID corpus.

    PYTHONPATH=src python examples/train_lm.py --rounds 300   # full run
    PYTHONPATH=src python examples/train_lm.py --rounds 20    # quick look

Uses the public API end to end: ModelConfig -> init_params -> DFLConfig ->
make_round_fn -> checkpointing. Loss should fall from ~ln(V) toward the
corpus entropy.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.core import DFLConfig, init_state, make_round_fn, ring
from repro.data.lm import SyntheticLM, lm_batches_for_dfl
from repro.models import ModelConfig, init_params, train_loss
from repro.optim import adamw, warmup_cosine

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=300)
ap.add_argument("--nodes", type=int, default=4)
ap.add_argument("--tau1", type=int, default=4)
ap.add_argument("--tau2", type=int, default=2)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--ckpt", default="")
args = ap.parse_args()

# ~100M params: 12L, d=768, standard GQA block (qwen3-ish reduced).
CFG = ModelConfig(
    name="qwen3-100m", arch_type="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32768,
    qk_norm=True, dtype=jnp.float32, attn_q_chunk=128, attn_kv_chunk=256,
    loss_seq_chunk=128, remat=False,
)

params, _ = init_params(CFG, jax.random.key(0))
n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
print(f"model: {CFG.name}  {n_params/1e6:.1f}M params, "
      f"{args.nodes} DFL nodes, ring topology")

dcfg = DFLConfig(tau1=args.tau1, tau2=args.tau2, topology=ring(args.nodes))
total_steps = args.rounds * args.tau1
opt = adamw(warmup_cosine(3e-4, warmup_steps=total_steps // 20,
                          total_steps=total_steps))
corpus = SyntheticLM(vocab_size=CFG.vocab_size, num_nodes=args.nodes,
                     noniid_alpha=0.5, branching=8)

state = init_state(params, args.nodes, opt, jax.random.key(1))
round_fn = jax.jit(make_round_fn(
    dcfg, lambda p, b, k: train_loss(p, b, CFG, k), opt))

t0 = time.time()
for r in range(args.rounds):
    batches = lm_batches_for_dfl(corpus, args.tau1, args.nodes, args.batch,
                                 args.seq, r)
    state, m = round_fn(state, batches)
    if (r + 1) % max(1, args.rounds // 50) == 0 or r == 0:
        dt = time.time() - t0
        toks = (r + 1) * args.tau1 * args.nodes * args.batch * args.seq
        print(f"round {r+1:4d}/{args.rounds} loss={float(m['loss']):.4f} "
              f"consensus={float(m['consensus_sq']):.2e} "
              f"{toks/dt:.0f} tok/s", flush=True)
    if args.ckpt and (r + 1) % 100 == 0:
        save_checkpoint(args.ckpt, r + 1, state.params,
                        {"loss": float(m["loss"])})
print(f"trained {args.rounds} rounds in {time.time()-t0:.0f}s")
