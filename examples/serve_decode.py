"""Serve a small model with batched requests: prefill + KV-cache decode.

    PYTHONPATH=src python examples/serve_decode.py --arch gemma3-4b

Exercises the serving path of the framework (the same prefill/decode_step
the production dry-run lowers at 32k/512k) on the reduced config, including
the sliding-window ring-buffer cache for gemma3 and the O(1) SSM state for
falcon-mamba.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, list_archs
from repro.models import decode_step, init_params, prefill

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="gemma3-4b", choices=list_archs())
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--prompt-len", type=int, default=48)
ap.add_argument("--gen", type=int, default=48)
args = ap.parse_args()

cfg = get_arch(args.arch).reduced
params, _ = init_params(cfg, jax.random.key(0))
max_len = args.prompt_len + args.gen

key = jax.random.key(1)
batch = {"tokens": jax.random.randint(
    key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
if cfg.has_memory_input:
    batch["memory"] = jax.random.normal(
        jax.random.fold_in(key, 1),
        (args.batch, cfg.memory_tokens or 16, cfg.memory_dim or cfg.d_model),
        jnp.float32)

prefill_fn = jax.jit(lambda p, b: prefill(p, b, cfg, max_len=max_len))
step_fn = jax.jit(lambda p, s, t: decode_step(p, s, t, cfg))

t0 = time.time()
logits, state = prefill_fn(params, batch)
logits.block_until_ready()
print(f"{cfg.name}: prefill {args.batch}x{args.prompt_len} in "
      f"{(time.time()-t0)*1e3:.0f} ms")

tok = (jnp.argmax(logits, -1)[:, None] % cfg.vocab_size).astype(jnp.int32)
seq = [tok]
t0 = time.time()
for _ in range(args.gen - 1):
    logits, state = step_fn(params, state, tok)
    tok = (jnp.argmax(logits, -1)[:, None] % cfg.vocab_size).astype(jnp.int32)
    seq.append(tok)
gen = jnp.concatenate(seq, 1)
gen.block_until_ready()
dt = time.time() - t0
print(f"decoded {args.gen} tokens/request: "
      f"{args.batch*(args.gen-1)/dt:.0f} tok/s aggregate")
print("first request tokens:", gen[0, :12].tolist())
assert bool(jnp.isfinite(logits).all())
print("OK")
