"""C-DFL compression sweep: accuracy-vs-bytes frontier (paper Fig. 10).

    PYTHONPATH=src python examples/compression_sweep.py

For each compression operator, trains the paper's CNN with C-DFL on the
10-node ring and prints the loss reached per GB of gossip traffic — the
communication-efficiency frontier the paper's wall-clock plot captures.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import RunSpec, run_dfl_cnn

VARIANTS = [
    ("uncompressed DFL", "", {}),
    ("top_k frac=0.5", "top_k", {"frac": 0.5}),
    ("rand_k frac=0.5", "rand_k", {"frac": 0.5}),
    ("qsgd s=16", "qsgd", {"levels": 16}),
    ("rand_gossip p=0.7", "rand_gossip", {"p": 0.7}),
]

print(f"{'variant':22s} {'loss':>8s} {'acc':>7s} {'GB sent':>8s} "
      f"{'loss/GB frontier':>16s}")
for label, comp, kw in VARIANTS:
    spec = RunSpec(name=f"sweep-{comp or 'none'}", tau1=4, tau2=4,
                   topology="ring", compression=comp, comp_kwargs=kw,
                   gamma=1.0 if not comp else 0.6, rounds=15)
    out = run_dfl_cnn(spec, log_every=5)
    h = out["history"]
    gb = h["gbits"][-1] / 8
    print(f"{label:22s} {h['loss'][-1]:8.4f} {h['test_acc'][-1]:7.3f} "
          f"{gb:8.2f} {h['loss'][-1]/max(gb,1e-9):16.4f}")
